//! Parity property tests for the zero-copy / blocked-kernel refactor:
//! the register-blocked (and threaded) matmul kernels and the in-place
//! attention path must produce outputs identical to straight-line
//! naive reference implementations (the pre-refactor kernels), across
//! odd shapes that straddle the 4-wide register block and the
//! thread-chunk boundaries. Both tiers sum each output element over k
//! in ascending order with one accumulator, so the expected diff is
//! exactly zero; the assertions allow <= 1e-6 for safety.
//!
//! Also guards the copy-on-write contract at the literal boundary:
//! passing a *borrowed* KV cache into attention must leave the
//! caller's tensor untouched, and the owned-transfer path must produce
//! the same outputs as the borrowed path.

use duoserve::coordinator::Engine;
use duoserve::memory::ExpertKey;
use duoserve::runtime::{kernels, ArgRef, Tensor};
use duoserve::util::Rng;

const CASES: u64 = 60;

fn randv(r: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (r.f64() * 2.0 - 1.0) as f32).collect()
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() <= 1e-6,
                "{what} elem {i}: got {g}, want {w}");
    }
}

// ------------------------------------------------------------------
// naive reference kernels (the pre-refactor implementations)
// ------------------------------------------------------------------

fn rms_norm_ref(x: &[f32], t: usize, d: usize, w: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    for i in 0..t {
        let row = &x[i * d..(i + 1) * d];
        let var: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-6).sqrt();
        for (j, &v) in row.iter().enumerate() {
            out[i * d + j] = v * inv * w[j];
        }
    }
    out
}

fn softmax_ref(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// The pre-refactor attention: full-cache clones + naive matmuls.
#[allow(clippy::too_many_arguments)]
fn attention_ref(h: &[f32], t: usize, d: usize, scalar: usize, decode: bool,
                 ln: &[f32], wq: &[f32], wk: &[f32], wv: &[f32], wo: &[f32],
                 kc: &[f32], vc: &[f32], kv_len: usize, n_heads: usize,
                 hd: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (pos0, valid_bound) = if decode {
        (scalar, scalar + 1)
    } else {
        (0usize, scalar)
    };
    let hn = rms_norm_ref(h, t, d, ln);
    let q = kernels::matmul_naive(&hn, t, d, wq, d);
    let k_new = kernels::matmul_naive(&hn, t, d, wk, d);
    let v_new = kernels::matmul_naive(&hn, t, d, wv, d);

    let mut kc2 = kc.to_vec();
    let mut vc2 = vc.to_vec();
    for i in 0..t {
        let p = pos0 + i;
        kc2[p * d..(p + 1) * d].copy_from_slice(&k_new[i * d..(i + 1) * d]);
        vc2[p * d..(p + 1) * d].copy_from_slice(&v_new[i * d..(i + 1) * d]);
    }

    let scale = 1.0 / (hd as f32).sqrt();
    let mut att_out = vec![0.0f32; t * d];
    let mut scores = vec![0.0f32; kv_len];
    for qi in 0..t {
        let q_abs = pos0 + qi;
        for head in 0..n_heads {
            let qrow = &q[qi * d + head * hd..qi * d + (head + 1) * hd];
            for kp in 0..kv_len {
                let masked = kp > q_abs || kp >= valid_bound;
                scores[kp] = if masked {
                    -1e9
                } else {
                    let krow =
                        &kc2[kp * d + head * hd..kp * d + (head + 1) * hd];
                    qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>()
                        * scale
                };
            }
            softmax_ref(&mut scores);
            let orow =
                &mut att_out[qi * d + head * hd..qi * d + (head + 1) * hd];
            for (kp, &w) in scores.iter().enumerate() {
                if w == 0.0 {
                    continue;
                }
                let vrow =
                    &vc2[kp * d + head * hd..kp * d + (head + 1) * hd];
                for (o, &v) in orow.iter_mut().zip(vrow) {
                    *o += w * v;
                }
            }
        }
    }
    let proj = kernels::matmul_naive(&att_out, t, d, wo, d);
    let mut out = h.to_vec();
    for (o, p) in out.iter_mut().zip(&proj) {
        *o += p;
    }
    (out, kc2, vc2)
}

fn silu_ref(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

// ------------------------------------------------------------------
// kernel parity
// ------------------------------------------------------------------

#[test]
fn prop_blocked_matmul_matches_naive_on_odd_shapes() {
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0xB10C);
        let m = r.range(1, 33);
        let k = r.range(1, 33);
        let n = r.range(1, 49);
        let a = randv(&mut r, m * k);
        let b = randv(&mut r, k * n);
        let want = kernels::matmul_naive(&a, m, k, &b, n);
        let bt = kernels::transpose(&b, k, n);
        let mut got = vec![0.0f32; m * n];
        kernels::matmul_bt_into(&a, m, k, &bt, n, &mut got);
        assert_close(&got, &want, &format!("seed {seed} ({m},{k},{n})"));
        // forced multi-threaded path on the same (small, odd) shape:
        // chunking across rows / columns must not change results
        for threads in [2usize, 3, 8] {
            let mut gt = vec![0.0f32; m * n];
            kernels::matmul_bt_threads(&a, m, k, &bt, n, &mut gt, threads);
            assert_eq!(gt, got,
                       "seed {seed} ({m},{k},{n}) x{threads} threads");
        }
    }
}

#[test]
fn prop_blocked_matmul_handles_zeros_like_naive() {
    // The naive kernel skips zero lhs entries; the blocked kernel adds
    // their exact-zero contributions. Results must still agree.
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0x0ED5);
        let m = r.range(1, 9);
        let k = r.range(1, 17);
        let n = r.range(1, 9);
        let mut a = randv(&mut r, m * k);
        for v in a.iter_mut() {
            if r.bool_with(0.5) {
                *v = 0.0;
            }
        }
        let b = randv(&mut r, k * n);
        let want = kernels::matmul_naive(&a, m, k, &b, n);
        let bt = kernels::transpose(&b, k, n);
        let mut got = vec![0.0f32; m * n];
        kernels::matmul_bt(&a, m, k, &bt, n, &mut got);
        assert_close(&got, &want, &format!("seed {seed}"));
    }
}

// ------------------------------------------------------------------
// component parity through the Executable boundary
// ------------------------------------------------------------------

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

#[test]
fn attention_decode_matches_reference_and_cow_protects_caller() {
    let e = engine();
    let sim = e.man.sim.clone();
    let d = sim.d_model;
    let rt = e.runtime();
    let exe = rt.load(&e.man.component_path("attn_decode").unwrap()).unwrap();
    let lw = &e.host.nonmoe.layers[0];
    let kvs = vec![sim.kv_len, sim.n_heads, sim.head_dim];

    for seed in 0..8u64 {
        let mut r = Rng::seed_from(seed ^ 0xA77E);
        let kc = Tensor::f32(randv(&mut r, sim.kv_len * d), kvs.clone());
        let vc = Tensor::f32(randv(&mut r, sim.kv_len * d), kvs.clone());
        let kc_before = kc.as_f32().unwrap().to_vec();
        let vc_before = vc.as_f32().unwrap().to_vec();
        let pos = r.range(0, sim.kv_len - 1);
        let h = Tensor::f32(randv(&mut r, d), vec![1, d]);
        let pos_t = Tensor::scalar_i32(pos as i32);

        // borrowed-KV path (copy-on-write)
        let out = exe
            .run_mixed(vec![
                ArgRef::T(&h), ArgRef::T(&pos_t), lw.ln_attn.arg(),
                lw.wq.arg(), lw.wk.arg(), lw.wv.arg(), lw.wo.arg(),
                ArgRef::T(&kc), ArgRef::T(&vc),
            ])
            .unwrap();

        let (want_h, want_kc, want_vc) = attention_ref(
            h.as_f32().unwrap(), 1, d, pos, true,
            lw.ln_attn.t.as_f32().unwrap(), lw.wq.t.as_f32().unwrap(),
            lw.wk.t.as_f32().unwrap(), lw.wv.t.as_f32().unwrap(),
            lw.wo.t.as_f32().unwrap(), &kc_before, &vc_before,
            sim.kv_len, sim.n_heads, sim.head_dim);

        assert_close(out[0].as_f32().unwrap(), &want_h,
                     &format!("seed {seed} h"));
        assert_close(out[1].as_f32().unwrap(), &want_kc,
                     &format!("seed {seed} kc"));
        assert_close(out[2].as_f32().unwrap(), &want_vc,
                     &format!("seed {seed} vc"));
        // COW contract: the caller's borrowed caches are untouched
        assert_eq!(kc.as_f32().unwrap(), kc_before.as_slice(),
                   "seed {seed}: borrowed k cache was mutated");
        assert_eq!(vc.as_f32().unwrap(), vc_before.as_slice(),
                   "seed {seed}: borrowed v cache was mutated");

        // owned-transfer path (in place): identical outputs
        let out2 = exe
            .run_mixed(vec![
                ArgRef::T(&h), ArgRef::T(&pos_t), lw.ln_attn.arg(),
                lw.wq.arg(), lw.wk.arg(), lw.wv.arg(), lw.wo.arg(),
                ArgRef::Own(kc.clone()), ArgRef::Own(vc.clone()),
            ])
            .unwrap();
        assert_eq!(out2[0], out[0], "seed {seed}: owned path h diverged");
        assert_eq!(out2[1], out[1], "seed {seed}: owned path kc diverged");
        assert_eq!(out2[2], out[2], "seed {seed}: owned path vc diverged");
    }
}

#[test]
fn attention_prefill_matches_reference_across_valid_lengths() {
    let e = engine();
    let sim = e.man.sim.clone();
    let d = sim.d_model;
    let rt = e.runtime();
    let exe =
        rt.load(&e.man.component_path("attn_prefill").unwrap()).unwrap();
    let lw = &e.host.nonmoe.layers[0];
    let kvs = vec![sim.kv_len, sim.n_heads, sim.head_dim];

    for seed in 0..6u64 {
        let mut r = Rng::seed_from(seed ^ 0x9E1F);
        let t = r.range(1, sim.max_seq);
        let valid = r.range(1, t);
        let kc = Tensor::zeros(&kvs);
        let vc = Tensor::zeros(&kvs);
        let h = Tensor::f32(randv(&mut r, t * d), vec![t, d]);
        let vlen = Tensor::scalar_i32(valid as i32);

        let out = exe
            .run_mixed(vec![
                ArgRef::T(&h), ArgRef::T(&vlen), lw.ln_attn.arg(),
                lw.wq.arg(), lw.wk.arg(), lw.wv.arg(), lw.wo.arg(),
                ArgRef::T(&kc), ArgRef::T(&vc),
            ])
            .unwrap();

        let zeros = vec![0.0f32; sim.kv_len * d];
        let (want_h, want_kc, want_vc) = attention_ref(
            h.as_f32().unwrap(), t, d, valid, false,
            lw.ln_attn.t.as_f32().unwrap(), lw.wq.t.as_f32().unwrap(),
            lw.wk.t.as_f32().unwrap(), lw.wv.t.as_f32().unwrap(),
            lw.wo.t.as_f32().unwrap(), &zeros, &zeros,
            sim.kv_len, sim.n_heads, sim.head_dim);

        assert_close(out[0].as_f32().unwrap(), &want_h,
                     &format!("seed {seed} t={t} valid={valid} h"));
        assert_close(out[1].as_f32().unwrap(), &want_kc,
                     &format!("seed {seed} kc"));
        assert_close(out[2].as_f32().unwrap(), &want_vc,
                     &format!("seed {seed} vc"));
    }
}

#[test]
fn expert_ffn_matches_reference() {
    let e = engine();
    let sim = e.man.sim.clone();
    let (d, f) = (sim.d_model, sim.d_ff);
    let rt = e.runtime();
    let &b = e.man.expert_buckets.first().unwrap();
    let exe = rt
        .load(&e.man.component_path(&format!("expert_t{b}")).unwrap())
        .unwrap();
    let w = e.host.expert_tensors(ExpertKey::routed(0, 0)).unwrap();

    for seed in 0..6u64 {
        let mut r = Rng::seed_from(seed ^ 0xFF17);
        let x = Tensor::f32(randv(&mut r, b * d), vec![b, d]);
        let out = exe
            .run_mixed(vec![ArgRef::T(&x), w.w1.arg(), w.w3.arg(),
                            w.w2.arg()])
            .unwrap();

        let xd = x.as_f32().unwrap();
        let mut up = kernels::matmul_naive(xd, b, d,
                                           w.w1.t.as_f32().unwrap(), f);
        let gatev = kernels::matmul_naive(xd, b, d,
                                          w.w3.t.as_f32().unwrap(), f);
        for (u, g) in up.iter_mut().zip(&gatev) {
            *u = silu_ref(*u) * g;
        }
        let want = kernels::matmul_naive(&up, b, f,
                                         w.w2.t.as_f32().unwrap(), d);
        assert_close(out[0].as_f32().unwrap(), &want,
                     &format!("seed {seed} expert"));
    }
}

#[test]
fn predictor_rejects_non_rank2_input_with_clear_error() {
    // Satellite guard: a rank-1 state must fail with a shape error,
    // not an index panic.
    let e = engine();
    if !e.has_mlp() {
        return;
    }
    let rt = e.runtime();
    let exe = rt
        .load(&e.man.resolve(&e.man.predictor.hlo))
        .unwrap();
    let bad = Tensor::f32(vec![0.0; e.man.predictor.input_dim],
                          vec![e.man.predictor.input_dim]);
    let err = exe.run(&[&bad]).unwrap_err();
    // the vendored anyhow's Debug rendering shows the whole chain
    let msg = format!("{err:?}");
    assert!(msg.contains("rank-2"), "unhelpful error: {msg}");
}
