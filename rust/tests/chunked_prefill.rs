//! Chunked-prefill behaviour tests:
//!
//! * **bit parity at full chunk** — with `prefill_chunk >= prompt
//!   length` the chunked driver must reproduce the monolithic path
//!   *exactly*: tokens, decode routing, expert-ledger counters,
//!   virtual-time makespan and (in continuous mode) the event
//!   schedule;
//! * **function invariance at any chunk** — smaller chunks change the
//!   virtual-time schedule but may never change a token or a decode
//!   routing decision, in either serving mode;
//! * **stall bound** — in continuous mode with decode priority (the
//!   default), the decode batch advances after every chunk while a
//!   prefill has chunks pending, so no inter-decode-step window
//!   contains more than one pending prefill chunk (new admissions
//!   defer too — see `admission_defers_to_owed_decode_between_chunks`
//!   in the scheduler's unit tests); with `decode_priority: false`
//!   the monolithic stall profile returns (the knob's contrast case).

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ContinuousConfig, Engine, ServeOptions,
                            ServeOutcome, ServerEvent};
use duoserve::experts::{ExpertStats, StagingMode};
use duoserve::workload::{assign_arrivals, generate_requests,
                         ArrivalProcess, Request};

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

/// Deterministic options: synchronous staging fixes the ledger's
/// staged/sync-acquire split (the threaded worker races acquire, by
/// design), so stats assertions can be exhaustive.
fn opts(chunk: Option<usize>) -> ServeOptions {
    let mut o = ServeOptions::new(PolicyKind::DuoServe,
                                  DeviceProfile::a6000());
    o.staging = StagingMode::Sync;
    o.prefill_chunk = chunk;
    o
}

fn requests(engine: &Engine, n: usize, seed: u64) -> Vec<Request> {
    let mut reqs = generate_requests(&engine.man, "squad", n, seed);
    for r in reqs.iter_mut() {
        r.n_decode = r.n_decode.min(5);
    }
    reqs
}

fn assert_stats_eq(a: &ExpertStats, b: &ExpertStats, what: &str) {
    assert_eq!(a.hits, b.hits, "{what}: cache hits diverged");
    assert_eq!(a.misses, b.misses, "{what}: cache misses diverged");
    assert_eq!(a.bytes_fetched, b.bytes_fetched,
               "{what}: transferred bytes diverged");
    assert_eq!(a.staged_acquires, b.staged_acquires,
               "{what}: staged acquires diverged");
    assert_eq!(a.sync_acquires, b.sync_acquires,
               "{what}: sync acquires diverged");
    assert_eq!(a.prefetch_hints, b.prefetch_hints,
               "{what}: prefetch hints diverged");
    assert_eq!(a.accuracy.total, b.accuracy.total,
               "{what}: accuracy totals diverged");
    assert_eq!(a.accuracy.exact, b.accuracy.exact);
    assert_eq!(a.accuracy.at_least_half, b.accuracy.at_least_half);
}

fn assert_bit_identical(a: &ServeOutcome, b: &ServeOutcome, what: &str) {
    assert_eq!(a.tokens, b.tokens, "{what}: token streams diverged");
    for (ea, eb) in a.episodes.iter().zip(&b.episodes) {
        assert_eq!(ea.steps, eb.steps, "{what}: decode routing diverged");
    }
    assert_eq!(a.summary.makespan, b.summary.makespan,
               "{what}: virtual-time makespan diverged");
    let ta: Vec<(f64, f64)> =
        a.metrics.iter().map(|m| (m.ttft, m.e2e)).collect();
    let tb: Vec<(f64, f64)> =
        b.metrics.iter().map(|m| (m.ttft, m.e2e)).collect();
    assert_eq!(ta, tb, "{what}: per-request ttft/e2e diverged");
    assert_stats_eq(&a.expert_stats, &b.expert_stats, what);
}

#[test]
fn full_chunk_is_bit_identical_to_monolithic_phase_bulk() {
    let e = engine();
    let reqs = requests(&e, 3, 29);
    let prompt_max = reqs.iter().map(|r| r.prompt.len()).max().unwrap();
    let base = e.serve(&reqs, &opts(None)).unwrap();
    assert!(base.oom.is_none());

    for chunk in [prompt_max, usize::MAX] {
        let out = e.serve(&reqs, &opts(Some(chunk))).unwrap();
        assert!(out.oom.is_none());
        assert_bit_identical(&base, &out, &format!("chunk={chunk}"));
        // One chunk per prefill, exactly like the monolithic counter.
        assert_eq!(out.summary.prefill_chunks, reqs.len() as u64);
    }
    assert_eq!(base.summary.prefill_chunks, reqs.len() as u64,
               "a monolithic prefill counts as one chunk");
}

#[test]
fn full_chunk_is_bit_identical_to_monolithic_continuous() {
    let e = engine();
    let mut reqs = requests(&e, 4, 37);
    assign_arrivals(&mut reqs,
                    &ArrivalProcess::Poisson { rate: 5.0, seed: 11 });
    let prompt_max = reqs.iter().map(|r| r.prompt.len()).max().unwrap();
    let ccfg = ContinuousConfig { max_in_flight: 2, queue_capacity: 16,
                                  ..ContinuousConfig::default() };

    let base = e.serve_continuous(&reqs, &opts(None), &ccfg).unwrap();
    assert!(base.oom.is_none());
    let out = e
        .serve_continuous(&reqs, &opts(Some(prompt_max)), &ccfg)
        .unwrap();
    assert!(out.oom.is_none());
    assert_bit_identical(&base, &out, "continuous chunk=prompt_max");
    assert_eq!(base.events, out.events,
               "full-chunk mode must replay the monolithic schedule");
    assert!(!out.events.iter().any(
        |ev| matches!(ev, ServerEvent::PrefillChunk { .. })),
        "a chunk covering the prompt must not emit chunk events");
}

#[test]
fn small_chunks_preserve_tokens_and_routing_phase_bulk() {
    let e = engine();
    let reqs = requests(&e, 3, 43);
    let base = e.serve(&reqs, &opts(None)).unwrap();
    assert!(base.oom.is_none());

    for chunk in [1usize, 3] {
        let out = e.serve(&reqs, &opts(Some(chunk))).unwrap();
        assert!(out.oom.is_none());
        assert_eq!(base.tokens, out.tokens,
                   "chunk={chunk}: prefill chunking changed the tokens");
        for (eb, eo) in base.episodes.iter().zip(&out.episodes) {
            assert_eq!(eb.steps, eo.steps,
                       "chunk={chunk}: decode routing diverged");
        }
        let want_chunks: u64 = reqs
            .iter()
            .map(|r| ((r.prompt.len() + chunk - 1) / chunk) as u64)
            .sum();
        assert_eq!(out.summary.prefill_chunks, want_chunks,
                   "chunk={chunk}: chunk counter wrong");
    }
}

#[test]
fn small_chunks_preserve_tokens_continuous() {
    let e = engine();
    let mut reqs = requests(&e, 4, 51);
    assign_arrivals(&mut reqs,
                    &ArrivalProcess::Poisson { rate: 6.0, seed: 3 });
    let ccfg = ContinuousConfig { max_in_flight: 3, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    let base = e.serve_continuous(&reqs, &opts(None), &ccfg).unwrap();
    assert!(base.oom.is_none());
    for chunk in [1usize, 3] {
        let out = e
            .serve_continuous(&reqs, &opts(Some(chunk)), &ccfg)
            .unwrap();
        assert!(out.oom.is_none());
        assert_eq!(base.tokens, out.tokens,
                   "chunk={chunk}: continuous chunking changed tokens");
    }
}

/// Build the late-arrival scenario: request 0 decodes for a long
/// stretch; request 1 arrives mid-decode with a long prompt.
fn stall_scenario(e: &Engine) -> Vec<Request> {
    let mut reqs = requests(e, 2, 61);
    reqs[0].prompt.truncate(8);
    reqs[0].n_decode = 24;
    // Stretch request 1's prompt towards max_seq (repeat its tokens).
    while reqs[1].prompt.len() < e.man.sim.max_seq - 4 {
        let t = reqs[1].prompt[reqs[1].prompt.len() % 7];
        reqs[1].prompt.push(t);
    }
    reqs[1].n_decode = 4;
    // Place request 1's arrival mid-way through request 0's decode.
    let probe = e.serve(&reqs[..1], &opts(None)).unwrap();
    assert!(probe.oom.is_none());
    let (t_first, t_end) = (probe.metrics[0].ttft, probe.metrics[0].e2e);
    assert!(t_end > t_first);
    reqs[0].arrival = 0.0;
    reqs[1].arrival = (t_first + t_end) / 2.0;
    reqs
}

/// Prefill chunk executions between consecutive decode steps, counted
/// from the first StepDone (before it no decoder can stall). Each
/// executed chunk emits exactly one of PrefillChunk / PrefillDone.
fn max_chunks_between_steps(events: &[ServerEvent]) -> usize {
    let mut seen_step = false;
    let mut since_step = 0usize;
    let mut worst = 0usize;
    for ev in events {
        match ev {
            ServerEvent::StepDone { .. } => {
                seen_step = true;
                since_step = 0;
            }
            ServerEvent::PrefillChunk { .. }
            | ServerEvent::PrefillDone { .. } if seen_step => {
                since_step += 1;
                worst = worst.max(since_step);
            }
            _ => {}
        }
    }
    worst
}

#[test]
fn decode_stall_is_bounded_by_one_chunk() {
    let e = engine();
    let reqs = stall_scenario(&e);
    let chunk = 4usize;
    let ccfg = ContinuousConfig { max_in_flight: 4, queue_capacity: 8,
                                  ..ContinuousConfig::default() };

    let mono = e.serve_continuous(&reqs, &opts(None), &ccfg).unwrap();
    let chunked = e
        .serve_continuous(&reqs, &opts(Some(chunk)), &ccfg)
        .unwrap();
    assert!(mono.oom.is_none() && chunked.oom.is_none());
    assert_eq!(mono.tokens, chunked.tokens,
               "chunking changed the function");

    // The scheduling property this PR exists for: while request 0
    // decodes, request 1's prefill advances at most one chunk per
    // scheduler iteration — every inter-decode-step window holds at
    // most one chunk.
    assert_eq!(max_chunks_between_steps(&chunked.events), 1,
               "a decoder stalled for more than one chunk");
    let n_chunk_events = chunked
        .events
        .iter()
        .filter(|ev| matches!(ev, ServerEvent::PrefillChunk { .. }))
        .count();
    let chunks_of = |plen: usize| (plen + chunk - 1) / chunk;
    assert_eq!(n_chunk_events,
               chunks_of(reqs[0].prompt.len()) - 1
                   + chunks_of(reqs[1].prompt.len()) - 1,
               "unexpected number of non-final chunks");

    // Contrast knob: without decode priority the pending chunks drain
    // back-to-back and the decoder eats a multi-chunk stall.
    let no_prio = ContinuousConfig { decode_priority: false, ..ccfg };
    let drained = e
        .serve_continuous(&reqs, &opts(Some(chunk)), &no_prio)
        .unwrap();
    assert!(drained.oom.is_none());
    assert_eq!(drained.tokens, chunked.tokens,
               "the priority knob changed the function");
    assert!(max_chunks_between_steps(&drained.events) > 1,
            "decode_priority=off should drain chunks back-to-back");
}
