//! Behavioural tests of the scheduling policies: overlap structure,
//! stream usage, cache discipline, and the QoS ordering the paper
//! claims. All run on the tiny artifact (`make artifacts-tiny`).

use std::path::PathBuf;

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{Engine, ServeOptions};
use duoserve::simx::StreamId;
use duoserve::workload::generate_requests;

fn artifacts_dir() -> PathBuf {
    duoserve::testkit::ensure_tiny()
}

fn engine() -> Engine {
    Engine::load(&artifacts_dir(), "mixtral-tiny").unwrap()
}

fn serve_one(engine: &Engine, policy: PolicyKind, record: bool)
             -> duoserve::coordinator::ServeOutcome {
    let reqs = generate_requests(&engine.man, "squad", 1, 7);
    let mut opts = ServeOptions::new(policy, DeviceProfile::a6000());
    opts.record_streams = record;
    engine.serve(&reqs, &opts).unwrap()
}

#[test]
fn duoserve_overlaps_comm_with_compute() {
    // The two-stream pipeline: during prefill, some transfer must be
    // in flight while the compute stream is busy (Fig. 4a).
    let e = engine();
    let out = serve_one(&e, PolicyKind::DuoServe, true);
    let trace = out.stream_trace.unwrap();
    let fetches: Vec<_> =
        trace.iter().filter(|o| o.stream == StreamId::Comm).collect();
    let computes: Vec<_> =
        trace.iter().filter(|o| o.stream == StreamId::Compute).collect();
    assert!(!fetches.is_empty() && !computes.is_empty());
    let overlap = fetches.iter().any(|f| {
        computes.iter().any(|c| f.start < c.end && c.start < f.end)
    });
    assert!(overlap, "no comm/compute overlap found for DuoServe");
}

#[test]
fn odf_never_overlaps_transfer_with_expert_compute() {
    // ODF's defining property: transfers sit on the critical path —
    // an expert's transfer never overlaps another expert computation.
    let e = engine();
    let out = serve_one(&e, PolicyKind::Odf, true);
    let trace = out.stream_trace.unwrap();
    let fetches: Vec<_> = trace
        .iter()
        .filter(|o| o.stream == StreamId::Comm)
        .collect();
    let experts: Vec<_> = trace
        .iter()
        .filter(|o| o.label.contains("expert"))
        .collect();
    for f in &fetches {
        for c in &experts {
            assert!(!(f.start < c.end && c.start < f.end),
                    "ODF fetch [{:.4},{:.4}] overlaps expert [{:.4},{:.4}]",
                    f.start, f.end, c.start, c.end);
        }
    }
}

#[test]
fn duoserve_uses_predict_stream_odf_does_not() {
    let e = engine();
    let duo = serve_one(&e, PolicyKind::DuoServe, true);
    let odf = serve_one(&e, PolicyKind::Odf, true);
    let busy = |out: &duoserve::coordinator::ServeOutcome| {
        out.stream_trace
            .as_ref()
            .unwrap()
            .iter()
            .filter(|o| o.stream == StreamId::Predict)
            .count()
    };
    assert!(busy(&duo) > 0, "DuoServe must use the predict stream");
    assert_eq!(busy(&odf), 0, "ODF must not use the predict stream");
}

#[test]
fn lfp_transfers_full_layers() {
    // LFP moves every expert of every layer at least once (prefill
    // alone covers E * L).
    let e = engine();
    let out = serve_one(&e, PolicyKind::Lfp, true);
    let trace = out.stream_trace.unwrap();
    let n_fetch = trace
        .iter()
        .filter(|o| o.stream == StreamId::Comm)
        .count();
    let sim = &e.man.sim;
    assert!(n_fetch >= sim.n_experts * sim.n_layers,
            "LFP fetched only {n_fetch} experts");
}

#[test]
fn duoserve_beats_odf_and_lfp_on_ttft_and_e2e() {
    // The headline QoS ordering (Fig. 5), on the tiny model.
    let e = engine();
    let duo = serve_one(&e, PolicyKind::DuoServe, false);
    let odf = serve_one(&e, PolicyKind::Odf, false);
    let lfp = serve_one(&e, PolicyKind::Lfp, false);
    let (d, o, l) = (&duo.metrics[0], &odf.metrics[0], &lfp.metrics[0]);
    assert!(d.ttft < o.ttft, "TTFT: duo {} !< odf {}", d.ttft, o.ttft);
    assert!(d.ttft < l.ttft, "TTFT: duo {} !< lfp {}", d.ttft, l.ttft);
    assert!(d.e2e < o.e2e, "E2E: duo {} !< odf {}", d.e2e, o.e2e);
    assert!(d.e2e < l.e2e, "E2E: duo {} !< lfp {}", d.e2e, l.e2e);
}

#[test]
fn memory_ordering_matches_table2() {
    // ODF <= DuoServe < LFP < MIF (Table II's shape).
    let e = engine();
    let peak = |p| serve_one(&e, p, false).peak_bytes;
    let odf = peak(PolicyKind::Odf);
    let duo = peak(PolicyKind::DuoServe);
    let lfp = peak(PolicyKind::Lfp);
    let mif = peak(PolicyKind::Mif);
    assert!(odf <= duo, "odf {odf} > duo {duo}");
    assert!(duo < lfp, "duo {duo} >= lfp {lfp}");
    // On the tiny config LFP (E x 2 layers) and MIF (2k x L layers)
    // coincide at 16 resident experts; the strict gap appears on the
    // zoo models (see the table2 bench).
    assert!(lfp <= mif, "lfp {lfp} > mif {mif}");
}

#[test]
fn batching_increases_total_throughput() {
    // Fig. 7's premise: batched decode amortises non-MoE work.
    let e = engine();
    let opts = ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());
    let reqs = generate_requests(&e.man, "squad", 4, 11);
    let single: f64 = reqs
        .iter()
        .map(|r| {
            let out = e.serve(std::slice::from_ref(r), &opts).unwrap();
            out.summary.tokens_per_sec
        })
        .sum::<f64>()
        / reqs.len() as f64;
    let batched = e.serve(&reqs, &opts).unwrap().summary.tokens_per_sec;
    assert!(batched > single,
            "batch-4 {batched:.2} tok/s !> single {single:.2} tok/s");
}

#[test]
fn decode_step_latency_positive_and_bounded() {
    let e = engine();
    let out = serve_one(&e, PolicyKind::DuoServe, false);
    for m in &out.metrics {
        assert_eq!(m.step_latencies.len(), m.tokens_out - 1);
        for &s in &m.step_latencies {
            assert!(s > 0.0 && s < 10.0, "step latency {s}");
        }
    }
}

#[test]
fn hit_rate_duoserve_above_odf() {
    // ODF never reuses cache entries; DuoServe's predictor prefetch
    // must produce a strictly higher hit rate.
    let e = engine();
    let duo = serve_one(&e, PolicyKind::DuoServe, false);
    let odf = serve_one(&e, PolicyKind::Odf, false);
    assert!(duo.hit_rate > odf.hit_rate,
            "duo {} !> odf {}", duo.hit_rate, odf.hit_rate);
}

#[test]
fn online_accuracy_recorded_for_duoserve_only() {
    let e = engine();
    let duo = serve_one(&e, PolicyKind::DuoServe, false);
    let lfp = serve_one(&e, PolicyKind::Lfp, false);
    assert!(duo.accuracy.total > 0, "DuoServe records accuracy");
    assert_eq!(lfp.accuracy.total, 0, "LFP must not predict");
}

#[test]
fn episodes_record_every_decode_step() {
    let e = engine();
    let out = serve_one(&e, PolicyKind::DuoServe, false);
    let m = &out.metrics[0];
    let ep = &out.episodes[0];
    assert_eq!(ep.steps.len(), m.tokens_out - 1);
    for step in &ep.steps {
        assert_eq!(step.len(), e.man.sim.n_layers);
        for sel in step {
            assert_eq!(sel.len(), e.man.sim.top_k);
        }
    }
}
