//! SLO-attainment tests of the continuous serving mode under a fixed
//! arrival trace: DuoServe must beat the on-demand-fetch baseline on
//! tail latency and attainment, and attainment must degrade
//! monotonically as the arrival rate rises (Fig. 6's QoS story, now
//! with real queueing).

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ClassPolicy, ContinuousConfig, Engine,
                            ServeOptions, ServeOutcome, ServerEvent};
use duoserve::experts::{ExpertProvider, Placement, StagedExpertProvider,
                        StagingMode};
use duoserve::memory::{CachePolicy, DeviceExpertCache, ExpertKey};
use duoserve::metrics::{slo_attainment, slo_attainment_for_class, SloReport,
                        SloSpec};
use duoserve::workload::{assign_arrivals, generate_requests,
                         ArrivalProcess, PriorityClass, Request};

const N_REQS: usize = 8;

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

fn requests(engine: &Engine) -> Vec<Request> {
    let mut reqs = generate_requests(&engine.man, "squad", N_REQS, 71);
    for r in reqs.iter_mut() {
        r.n_decode = r.n_decode.min(6);
    }
    reqs
}

/// Worst-case isolated (unloaded) TTFT / E2E across the request set,
/// under DuoServe — the no-queueing baseline the SLO is written
/// against.
fn isolated_worst(engine: &Engine, reqs: &[Request]) -> (f64, f64) {
    let opts = ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());
    let mut worst_ttft = 0.0f64;
    let mut worst_e2e = 0.0f64;
    for r in reqs {
        let out = engine.serve(std::slice::from_ref(r), &opts).unwrap();
        assert!(out.oom.is_none());
        worst_ttft = worst_ttft.max(out.metrics[0].ttft);
        worst_e2e = worst_e2e.max(out.metrics[0].e2e);
    }
    (worst_ttft, worst_e2e)
}

fn run_at_spacing(engine: &Engine, reqs: &[Request], policy: PolicyKind,
                  spacing: f64) -> ServeOutcome {
    let mut reqs = reqs.to_vec();
    let times: Vec<f64> = (0..reqs.len()).map(|i| i as f64 * spacing).collect();
    assign_arrivals(&mut reqs, &ArrivalProcess::Trace(times));
    let ccfg = ContinuousConfig { max_in_flight: 4, queue_capacity: 64,
                                  ..ContinuousConfig::default() };
    let opts = ServeOptions::new(policy, DeviceProfile::a6000());
    let out = engine.serve_continuous(&reqs, &opts, &ccfg).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.metrics.len(), reqs.len());
    out
}

fn report(out: &ServeOutcome, spec: &SloSpec) -> SloReport {
    slo_attainment(&out.metrics, spec)
}

#[test]
fn attainment_degrades_monotonically_with_arrival_rate() {
    let e = engine();
    let reqs = requests(&e);
    let (iso_ttft, iso_e2e) = isolated_worst(&e, &reqs);
    let spec = SloSpec { ttft: 1.5 * iso_ttft, e2e: 1.5 * iso_e2e };

    // Same request set, same FIFO trace shape, three arrival rates:
    // fully separated, moderately overlapped, and a burst.
    let low = run_at_spacing(&e, &reqs, PolicyKind::DuoServe, 3.0 * iso_e2e);
    let mid = run_at_spacing(&e, &reqs, PolicyKind::DuoServe, 0.6 * iso_e2e);
    let high = run_at_spacing(&e, &reqs, PolicyKind::DuoServe, 0.0);

    let (a_low, a_mid, a_high) =
        (report(&low, &spec), report(&mid, &spec), report(&high, &spec));

    assert!((a_low.joint_attainment - 1.0).abs() < 1e-12,
            "unloaded attainment must be 100%, got {:.3}",
            a_low.joint_attainment);
    assert!(a_mid.joint_attainment <= a_low.joint_attainment + 1e-12);
    assert!(a_high.joint_attainment <= a_mid.joint_attainment + 1e-12,
            "attainment rose with load: burst {:.3} > mid {:.3}",
            a_high.joint_attainment, a_mid.joint_attainment);
    assert!(a_high.joint_attainment < a_low.joint_attainment,
            "burst load must violate some SLOs");
    // Under backlog the queueing component dominates TTFT.
    assert!(high.summary.p95_ttft > low.summary.p95_ttft);
}

#[test]
fn duoserve_beats_odf_on_tail_latency_and_attainment_under_load() {
    let e = engine();
    let reqs = requests(&e);
    let (iso_ttft, iso_e2e) = isolated_worst(&e, &reqs);
    let spec = SloSpec { ttft: 1.5 * iso_ttft, e2e: 1.5 * iso_e2e };

    // A burst: every request arrives at t=0 and queues.
    let duo = run_at_spacing(&e, &reqs, PolicyKind::DuoServe, 0.0);
    let odf = run_at_spacing(&e, &reqs, PolicyKind::Odf, 0.0);

    assert!(duo.summary.p95_ttft < odf.summary.p95_ttft,
            "p95 TTFT: duo {} !< odf {}",
            duo.summary.p95_ttft, odf.summary.p95_ttft);
    assert!(duo.summary.p95_e2e < odf.summary.p95_e2e,
            "p95 E2E: duo {} !< odf {}",
            duo.summary.p95_e2e, odf.summary.p95_e2e);

    let (a_duo, a_odf) = (report(&duo, &spec), report(&odf, &spec));
    assert!(a_duo.ttft_attainment >= a_odf.ttft_attainment,
            "TTFT attainment: duo {:.3} < odf {:.3}",
            a_duo.ttft_attainment, a_odf.ttft_attainment);
    assert!(a_duo.joint_attainment >= a_odf.joint_attainment,
            "joint attainment: duo {:.3} < odf {:.3}",
            a_duo.joint_attainment, a_odf.joint_attainment);
    // The first request runs unloaded, so DuoServe attains at least it.
    assert!(a_duo.joint_attainment > 0.0,
            "DuoServe should attain at least the unqueued request");
}

#[test]
fn chunked_prefill_bounds_stalled_decoder_itl() {
    // The QoS story of chunked prefill: a long prompt arriving while
    // another request decodes no longer stalls the decoder for the
    // whole prefill — its worst inter-token latency is bounded by one
    // chunk, and the pooled p95 ITL can only improve.
    let e = engine();
    let mut reqs = requests(&e);
    reqs.truncate(2);
    reqs[0].prompt.truncate(8);
    reqs[0].n_decode = 24;
    while reqs[1].prompt.len() < e.man.sim.max_seq - 4 {
        let t = reqs[1].prompt[reqs[1].prompt.len() % 5];
        reqs[1].prompt.push(t);
    }
    reqs[1].n_decode = 4;
    let opts = ServeOptions::new(PolicyKind::DuoServe,
                                 DeviceProfile::a6000());
    let probe = e.serve(&reqs[..1], &opts).unwrap();
    assert!(probe.oom.is_none());
    reqs[0].arrival = 0.0;
    reqs[1].arrival =
        (probe.metrics[0].ttft + probe.metrics[0].e2e) / 2.0;

    let ccfg = ContinuousConfig { max_in_flight: 4, queue_capacity: 8,
                                  ..ContinuousConfig::default() };
    let mono = e.serve_continuous(&reqs, &opts, &ccfg).unwrap();
    let mut chunked_opts = opts.clone();
    chunked_opts.prefill_chunk = Some(2);
    let chunked = e.serve_continuous(&reqs, &chunked_opts, &ccfg).unwrap();
    assert!(mono.oom.is_none() && chunked.oom.is_none());
    assert_eq!(mono.tokens, chunked.tokens);

    let max_itl = |out: &ServeOutcome| -> f64 {
        out.metrics
            .iter()
            .find(|m| m.req_id == 0)
            .unwrap()
            .step_latencies
            .iter()
            .cloned()
            .fold(0.0, f64::max)
    };
    assert!(max_itl(&chunked) < max_itl(&mono),
            "chunking did not shrink the stalled decoder's worst ITL: \
             {} !< {}", max_itl(&chunked), max_itl(&mono));
    // The whole-prompt stall dominates the monolithic run's tail: its
    // worst step dwarfs even the chunked run's p95.
    assert!(max_itl(&mono) > chunked.summary.p95_itl,
            "monolithic stall should exceed the chunked tail");
    // The ITL percentiles are live in the summary for both runs.
    assert!(mono.summary.p50_itl > 0.0 && mono.summary.p95_itl > 0.0);
    assert!(chunked.summary.p50_itl > 0.0
            && chunked.summary.p95_itl > 0.0);
    assert!(chunked.summary.p95_itl >= chunked.summary.p50_itl);
    assert!(chunked.summary.prefill_chunks > mono.summary.prefill_chunks,
            "chunked run should execute more prefill chunks");
}

#[test]
fn replicate_hot_sharding_raises_aggregate_hit_rate_under_burst() {
    // The multi-device QoS claim: at equal *per-shard* capacity (each
    // simulated device keeps the same k-slot cache DuoServe always
    // had), four shards with hot-expert replication must beat the
    // single device's aggregate hit rate under burst load. Mechanism:
    // a lockstep decode batch routes up to B*top_k distinct experts
    // per layer into k slots on one device (admission thrash), while
    // sharding spreads the same keys across four home caches that can
    // actually retain them.
    let e = engine();
    let mut reqs = requests(&e);
    let times = vec![0.0; reqs.len()];
    assign_arrivals(&mut reqs, &ArrivalProcess::Trace(times));
    let ccfg = ContinuousConfig { max_in_flight: 4, queue_capacity: 64,
                                  ..ContinuousConfig::default() };
    let mk = |shards: Option<usize>| {
        let mut o = ServeOptions::new(PolicyKind::DuoServe,
                                      DeviceProfile::a6000());
        o.staging = StagingMode::Sync;
        o.shards = shards;
        o.placement = Placement::ReplicateHot;
        o
    };

    let flat = e.serve_continuous(&reqs, &mk(None), &ccfg).unwrap();
    let sharded = e.serve_continuous(&reqs, &mk(Some(4)), &ccfg).unwrap();
    assert!(flat.oom.is_none() && sharded.oom.is_none());
    assert_eq!(flat.tokens, sharded.tokens,
               "sharding must never change the tokens");

    assert_eq!(sharded.shard_stats.len(), 4);
    assert!(sharded.hit_rate > flat.hit_rate,
            "4-shard replicate-hot hit rate {:.3} must beat the \
             single device's {:.3}",
            sharded.hit_rate, flat.hit_rate);
    // Every simulated device saw traffic, and the balance metric is a
    // well-formed min/max touch ratio.
    for (i, s) in sharded.shard_stats.iter().enumerate() {
        assert!(s.hits + s.misses > 0, "shard {i} saw no expert traffic");
    }
    assert!(sharded.shard_balance > 0.0 && sharded.shard_balance <= 1.0,
            "shard balance out of range: {}", sharded.shard_balance);
    // The single-device run reports the degenerate shard view.
    assert_eq!(flat.shard_stats.len(), 1);
    assert_eq!(flat.shard_balance, 1.0);
}

#[test]
fn classes_keep_interactive_ttft_attainment_alive_under_batch_flood() {
    // The PR's headline QoS claim: a t=0 flood of batch requests ahead
    // of a few interactive ones starves interactive TTFT under the
    // class-blind FIFO, while weighted per-class queues pull the
    // interactive requests to the front — strictly better interactive
    // attainment against the same SLO, same tokens.
    let e = engine();
    let mut reqs = generate_requests(&e.man, "squad", 13, 77);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.n_decode = 3 + (i % 3);
        r.class = if i < 10 { PriorityClass::Batch }
                  else { PriorityClass::Interactive };
    }
    assign_arrivals(&mut reqs, &ArrivalProcess::Closed);
    let base = ContinuousConfig { max_in_flight: 1, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    let classed_cfg = ContinuousConfig {
        classes: Some(ClassPolicy::default()),
        ..base.clone()
    };
    let opts = ServeOptions::new(PolicyKind::DuoServe,
                                 DeviceProfile::a6000());
    let blind = e.serve_continuous(&reqs, &opts, &base).unwrap();
    let classed = e.serve_continuous(&reqs, &opts, &classed_cfg).unwrap();
    assert!(blind.oom.is_none() && classed.oom.is_none());
    assert_eq!(blind.tokens, classed.tokens,
               "class scheduling must never change the tokens");
    assert_eq!(blind.metrics.len(), reqs.len());
    assert_eq!(classed.metrics.len(), reqs.len());

    let interactive_ttfts = |out: &ServeOutcome| -> Vec<f64> {
        out.metrics
            .iter()
            .filter(|m| m.class == PriorityClass::Interactive)
            .map(|m| m.ttft)
            .collect()
    };
    let worst_classed = interactive_ttfts(&classed)
        .into_iter().fold(0.0, f64::max);
    let best_blind = interactive_ttfts(&blind)
        .into_iter().fold(f64::INFINITY, f64::min);
    // FIFO serves all ten batch prompts first; the weighted queues
    // admit every interactive request within the first few slots — the
    // two TTFT ranges must not even overlap.
    assert!(worst_classed < best_blind,
            "classed worst interactive TTFT {worst_classed} should beat \
             the blind best {best_blind}");

    // An SLO straddling the gap: interactive attainment goes from
    // total miss to total attainment; batch keeps paying its own way.
    let spec = SloSpec { ttft: (worst_classed + best_blind) / 2.0,
                         e2e: f64::INFINITY };
    let a_classed =
        slo_attainment_for_class(&classed.metrics, &spec,
                                 PriorityClass::Interactive);
    let a_blind =
        slo_attainment_for_class(&blind.metrics, &spec,
                                 PriorityClass::Interactive);
    assert_eq!(a_classed.n_requests, 3);
    assert_eq!(a_blind.n_requests, 3);
    assert!(a_classed.ttft_attainment > a_blind.ttft_attainment,
            "classes must strictly beat the class-blind run: {} !> {}",
            a_classed.ttft_attainment, a_blind.ttft_attainment);
    assert!((a_classed.ttft_attainment - 1.0).abs() < 1e-12);
    assert!(a_blind.ttft_attainment < 1e-12);
    // Per-class tails are attached and ordered the same way.
    let cl = classed.summary.class_latency.expect("classes were on");
    assert_eq!(cl[0].n_requests, 3);
    assert_eq!(cl[2].n_requests, 10);
    assert!(cl[0].p95_ttft < cl[2].p95_ttft,
            "interactive p95 TTFT should undercut batch under the flood");
}

#[test]
fn auto_chunk_keeps_the_stall_bound_under_a_shifting_decode_batch() {
    // `--prefill-chunk auto` sizes chunks from the measured decode
    // step cost, so a long prompt landing on a live (and growing)
    // decode batch still stalls each decoder by roughly one step — and
    // the event schedule keeps the chunked-prefill protocol's bound of
    // at most one pending chunk per decode window.
    let e = engine();
    let mut reqs = requests(&e);
    reqs.truncate(3);
    reqs[0].prompt.truncate(8);
    reqs[0].n_decode = 24;
    while reqs[1].prompt.len() < e.man.sim.max_seq - 4 {
        let t = reqs[1].prompt[reqs[1].prompt.len() % 5];
        reqs[1].prompt.push(t);
    }
    reqs[1].n_decode = 4;
    reqs[2].prompt.truncate(8);
    reqs[2].n_decode = 12;
    let opts = ServeOptions::new(PolicyKind::DuoServe,
                                 DeviceProfile::a6000());
    let probe = e.serve(&reqs[..1], &opts).unwrap();
    assert!(probe.oom.is_none());
    let (ttft0, e2e0) = (probe.metrics[0].ttft, probe.metrics[0].e2e);
    reqs[0].arrival = 0.0;
    // Request 2 joins the decode batch early; the long prompt then
    // lands on a *two*-request batch mid-decode.
    reqs[2].arrival = ttft0 * 1.1;
    reqs[1].arrival = (ttft0 + e2e0) / 2.0;

    let ccfg = ContinuousConfig { max_in_flight: 4, queue_capacity: 8,
                                  ..ContinuousConfig::default() };
    let mono = e.serve_continuous(&reqs, &opts, &ccfg).unwrap();
    let mut auto_opts = opts.clone();
    auto_opts.prefill_chunk_auto = true;
    let auto = e.serve_continuous(&reqs, &auto_opts, &ccfg).unwrap();
    assert!(mono.oom.is_none() && auto.oom.is_none());
    assert_eq!(mono.tokens, auto.tokens,
               "chunk autotuning must never change the tokens");

    // The autotuner actually split the long prefill, and the stalled
    // decoder's worst inter-token latency shrank for it.
    assert!(auto.summary.prefill_chunks > mono.summary.prefill_chunks,
            "auto chunking never split a prefill");
    let max_itl = |out: &ServeOutcome| -> f64 {
        out.metrics
            .iter()
            .find(|m| m.req_id == 0)
            .unwrap()
            .step_latencies
            .iter()
            .cloned()
            .fold(0.0, f64::max)
    };
    assert!(max_itl(&auto) < max_itl(&mono),
            "auto chunking did not shrink the stalled decoder's worst \
             ITL: {} !< {}", max_itl(&auto), max_itl(&mono));

    // Event-level stall bound: once decoding has begun, every window
    // between consecutive decode steps holds at most one pending
    // prefill chunk.
    let mut seen_step = false;
    let mut chunks_in_window = 0usize;
    let mut total_chunks = 0usize;
    for ev in &auto.events {
        match ev {
            ServerEvent::StepDone { .. } => {
                seen_step = true;
                chunks_in_window = 0;
            }
            ServerEvent::PrefillChunk { .. } => {
                total_chunks += 1;
                if seen_step {
                    chunks_in_window += 1;
                    assert!(chunks_in_window <= 1,
                            "two pending chunks ran between decode steps");
                }
            }
            _ => {}
        }
    }
    assert!(total_chunks > 0, "no pending chunks were ever recorded");
}

#[test]
fn value_policy_beats_lru_hit_rate_under_burst() {
    // The eviction-policy QoS claim at equal capacity: a bursty access
    // pattern with one hot expert plus a stream of one-shot experts
    // thrashes a pure-LRU cache (the fresh one-shots always look most
    // recent, so the hot expert is the perpetual victim), while the
    // bytes-normalized value credit keeps the hot expert resident from
    // its first round of touches on. Same cache capacity, identical
    // access trace, strictly more hits — which on the serving path is
    // strictly less expert-transfer time on the critical path.
    let run = |policy: CachePolicy| -> (u64, u64) {
        let mut p = StagedExpertProvider::detached(
            DeviceExpertCache::with_policy(2, 0, policy, 1), 1);
        let hot = ExpertKey::routed(0, 0);
        let mut now = 0.0;
        let mut step = |p: &mut StagedExpertProvider, key| {
            if p.touch(key, now).is_none() {
                p.admit(key, now + 1.0, now);
            }
            now += 1.0;
        };
        for round in 0..8usize {
            // Three touches of the hot expert, then two one-shots that
            // fill the second slot and force an eviction decision.
            for _ in 0..3 {
                step(&mut p, hot);
            }
            step(&mut p, ExpertKey::routed(0, 1 + 2 * round));
            step(&mut p, ExpertKey::routed(0, 2 + 2 * round));
        }
        let s = p.stats();
        (s.hits, s.misses)
    };

    let (lru_hits, lru_misses) = run(CachePolicy::Lru);
    let (val_hits, val_misses) = run(CachePolicy::Value);
    // Identical trace: the touch totals must agree exactly.
    assert_eq!(lru_hits + lru_misses, val_hits + val_misses,
               "the two policies saw different traces");
    assert!(val_hits > lru_hits,
            "value policy must strictly beat LRU on the burst trace: \
             {val_hits} !> {lru_hits}");
    // The mechanism, pinned exactly: LRU re-fetches the hot expert
    // every round (2 hits/round); value credit retains it after the
    // first round (3 hits/round thereafter).
    assert_eq!(lru_hits, 16);
    assert_eq!(val_hits, 23);
}

#[test]
fn queue_delay_accounts_for_ttft_gap() {
    // Bookkeeping consistency: TTFT measured from arrival equals the
    // queueing delay plus the on-engine prefill latency, so TTFT must
    // always be at least the queue delay.
    let e = engine();
    let reqs = requests(&e);
    let out = run_at_spacing(&e, &reqs, PolicyKind::DuoServe, 0.0);
    for m in &out.metrics {
        assert!(m.queue_delay >= 0.0);
        assert!(m.ttft >= m.queue_delay - 1e-12,
                "req {}: ttft {} < queue delay {}", m.req_id, m.ttft,
                m.queue_delay);
        assert!(m.e2e >= m.ttft - 1e-12);
    }
}
