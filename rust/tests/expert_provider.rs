//! ExpertProvider subsystem tests:
//!
//! * **accounting parity** — hit/miss/bytes/accuracy counters live in
//!   one ledger, so the phase-bulk and continuous serving modes must
//!   report identical accounting for the same request set;
//! * **prefetch-worker determinism** — the threaded staging pipeline
//!   must produce bit-identical tokens, routing and virtual-time
//!   results to the synchronous provider (staging is pure delivery);
//! * **staging identity** — the worker must hand out the host pool's
//!   exact tensors (`Arc` pointer equality), never a diverging copy.

use std::sync::Arc;

use duoserve::config::{DeviceProfile, Manifest, PolicyKind};
use duoserve::coordinator::{ContinuousConfig, Engine, ServeOptions};
use duoserve::experts::{ExpertProvider, PrefetchWorker, StagedExpertProvider,
                        StagingMode};
use duoserve::memory::{DeviceExpertCache, ExpertKey, HostPool};
use duoserve::runtime::Runtime;
use duoserve::workload::generate_requests;

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

#[test]
fn phase_bulk_and_continuous_accounting_parity() {
    // Same request set, both serving modes: the centralized ledger
    // must make every counter agree exactly (the drift the provider
    // refactor is designed to rule out).
    let e = engine();
    let reqs = generate_requests(&e.man, "squad", 3, 17); // arrival 0
    let opts = ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());
    let bulk = e.serve(&reqs, &opts).unwrap();
    assert!(bulk.oom.is_none());

    let ccfg = ContinuousConfig {
        max_in_flight: reqs.len(),
        queue_capacity: reqs.len() + 4,
        ..ContinuousConfig::default()
    };
    let cont = e.serve_continuous(&reqs, &opts, &ccfg).unwrap();
    assert!(cont.oom.is_none());
    assert_eq!(cont.rejected, 0);

    assert_eq!(bulk.tokens, cont.tokens, "token streams diverged");
    let (b, c) = (bulk.expert_stats, cont.expert_stats);
    assert_eq!(b.hits, c.hits, "cache hits diverged across modes");
    assert_eq!(b.misses, c.misses, "cache misses diverged across modes");
    assert_eq!(b.bytes_fetched, c.bytes_fetched,
               "transferred bytes diverged across modes");
    assert_eq!(b.accuracy.total, c.accuracy.total,
               "accuracy observation counts diverged");
    assert_eq!(b.accuracy.exact, c.accuracy.exact);
    assert_eq!(b.accuracy.at_least_half, c.accuracy.at_least_half);
    assert!((bulk.hit_rate - cont.hit_rate).abs() < 1e-12,
            "hit rate diverged: {} vs {}", bulk.hit_rate, cont.hit_rate);
    // The outcome's headline fields are the ledger's, not a second set
    // of counters.
    assert!((bulk.hit_rate - b.hit_rate()).abs() < 1e-12);
    assert_eq!(bulk.accuracy.total, b.accuracy.total);
}

#[test]
fn threaded_prefetch_matches_sync_provider_bit_exactly() {
    // The PrefetchWorker thread only changes *when* weights are
    // staged, never *which* weights: tokens, routing paths and the
    // virtual-time schedule must be identical with and without it.
    let e = engine();
    let reqs = generate_requests(&e.man, "orca", 3, 23);
    let threaded = ServeOptions::new(PolicyKind::DuoServe,
                                     DeviceProfile::a6000());
    assert_eq!(threaded.staging, StagingMode::Threaded);
    let mut sync = ServeOptions::new(PolicyKind::DuoServe,
                                     DeviceProfile::a6000());
    sync.staging = StagingMode::Sync;

    let a = e.serve(&reqs, &threaded).unwrap();
    let b = e.serve(&reqs, &sync).unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "staging mode changed the tokens");
    for (ea, eb) in a.episodes.iter().zip(&b.episodes) {
        assert_eq!(ea.steps, eb.steps, "staging mode changed the routing");
    }
    assert_eq!(a.summary.makespan, b.summary.makespan,
               "staging mode leaked into virtual time");
    assert_eq!(a.expert_stats.hits, b.expert_stats.hits);
    assert_eq!(a.expert_stats.misses, b.expert_stats.misses);

    // Acquire accounting is exhaustive: every functional fetch is
    // either staged or synchronous, and the total is mode-invariant.
    assert_eq!(a.expert_stats.acquires(), b.expert_stats.acquires(),
               "total weight acquisitions diverged");
    assert_eq!(b.expert_stats.staged_acquires, 0,
               "sync provider must never report staged acquires");
    assert_eq!(b.expert_stats.prefetch_hints, 0,
               "sync provider must ignore prefetch hints");
    assert!(a.expert_stats.prefetch_hints > 0,
            "threaded provider received no staging hints");
}

#[test]
fn expert_fanout_keeps_the_ledger_identical_to_serial() {
    // Threaded expert-group execution pre-acquires every group's
    // weights on the caller thread (in serial order) before fanning
    // out, so the ledger must be *exactly* the serial ledger — every
    // counter, not just totals. Sync staging makes the
    // staged/sync-acquire split deterministic too, so the assertion
    // can be complete.
    let e = engine();
    let reqs = generate_requests(&e.man, "orca", 4, 41);
    let mut serial = ServeOptions::new(PolicyKind::DuoServe,
                                       DeviceProfile::a6000());
    serial.staging = StagingMode::Sync;
    serial.expert_fanout = false;
    let mut fanned = serial.clone();
    fanned.expert_fanout = true;

    let a = e.serve(&reqs, &serial).unwrap();
    let b = e.serve(&reqs, &fanned).unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "expert fan-out changed the tokens");

    let (sa, sb) = (a.expert_stats, b.expert_stats);
    assert_eq!(sa.hits, sb.hits, "fan-out changed cache hits");
    assert_eq!(sa.misses, sb.misses, "fan-out changed cache misses");
    assert_eq!(sa.bytes_fetched, sb.bytes_fetched,
               "fan-out changed transferred bytes");
    assert_eq!(sa.staged_acquires, sb.staged_acquires,
               "fan-out changed staged acquires");
    assert_eq!(sa.sync_acquires, sb.sync_acquires,
               "fan-out changed sync acquires");
    assert_eq!(sa.prefetch_hints, sb.prefetch_hints,
               "fan-out changed prefetch hints");
    assert_eq!(sa.accuracy.total, sb.accuracy.total);
    assert_eq!(sa.accuracy.exact, sb.accuracy.exact);
    assert_eq!(sa.accuracy.at_least_half, sb.accuracy.at_least_half);
}

#[test]
fn no_overlap_ablation_forces_the_sync_provider() {
    use duoserve::coordinator::engine::Ablation;
    let e = engine();
    let reqs = generate_requests(&e.man, "squad", 1, 7);
    let opts = ServeOptions::ablated(PolicyKind::DuoServe,
                                     DeviceProfile::a6000(),
                                     Ablation::NoOverlap);
    let out = e.serve(&reqs, &opts).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.expert_stats.staged_acquires, 0,
               "NoOverlap must serve through the synchronous provider");
    assert_eq!(out.expert_stats.prefetch_hints, 0);
    assert!(out.expert_stats.sync_acquires > 0);
}

#[test]
fn worker_stages_the_host_pools_exact_tensors() {
    let dir = duoserve::testkit::ensure_tiny();
    let man = Manifest::load(&dir, "mixtral-tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    let pool = Arc::new(HostPool::load(&man, &rt).unwrap());
    let w = PrefetchWorker::spawn(pool.clone());
    let keys: Vec<ExpertKey> =
        (0..man.sim.n_experts).map(|e| ExpertKey::routed(0, e)).collect();
    w.stage(keys.clone());
    w.drain();
    assert_eq!(w.staged_len(), keys.len());
    for key in keys {
        let staged = w.staged_get(key).expect("key not staged after drain");
        let direct = pool.expert_tensors(key).unwrap();
        assert!(Arc::ptr_eq(&staged, &direct),
                "worker delivered a diverging copy for {key:?}");
    }
    // retire drops staged layers below the watermark
    w.retire_below(1);
    w.drain();
    assert_eq!(w.staged_len(), 0);
}

#[test]
fn provider_acquire_counts_staged_and_sync_paths() {
    let dir = duoserve::testkit::ensure_tiny();
    let man = Manifest::load(&dir, "mixtral-tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    let pool = Arc::new(HostPool::load(&man, &rt).unwrap());
    let mut p = StagedExpertProvider::new(pool.clone(),
                                          DeviceExpertCache::new(2, 2), 64,
                                          StagingMode::Threaded);
    let key = ExpertKey::routed(1, 0);
    let direct = pool.expert_tensors(key).unwrap();

    // cold acquire: synchronous fallback, same tensors
    let a = p.acquire(key).unwrap();
    assert!(Arc::ptr_eq(&a, &direct));

    // staged acquire: hint -> worker delivery -> staged-table hit
    p.prefetch(&[key]);
    p.worker().unwrap().drain();
    let b = p.acquire(key).unwrap();
    assert!(Arc::ptr_eq(&b, &direct));

    let s = p.stats();
    assert_eq!(s.sync_acquires, 1);
    assert_eq!(s.staged_acquires, 1);
    assert_eq!(s.prefetch_hints, 1);
}
