//! ExpertProvider subsystem tests:
//!
//! * **accounting parity** — hit/miss/bytes/accuracy counters live in
//!   one ledger, so the phase-bulk and continuous serving modes must
//!   report identical accounting for the same request set;
//! * **prefetch-worker determinism** — the threaded staging pipeline
//!   must produce bit-identical tokens, routing and virtual-time
//!   results to the synchronous provider (staging is pure delivery);
//! * **staging identity** — the worker must hand out the host pool's
//!   exact tensors (`Arc` pointer equality), never a diverging copy.

use std::sync::Arc;

use duoserve::config::{DeviceProfile, Manifest, PolicyKind};
use duoserve::coordinator::{ContinuousConfig, Engine, ServeOptions};
use duoserve::experts::{ExpertProvider, ExpertStats, Placement,
                        PrefetchWorker, StagedExpertProvider, StagingMode};
use duoserve::memory::{DeviceExpertCache, ExpertKey, HostPool};
use duoserve::runtime::Runtime;
use duoserve::workload::generate_requests;

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

#[test]
fn phase_bulk_and_continuous_accounting_parity() {
    // Same request set, both serving modes: the centralized ledger
    // must make every counter agree exactly (the drift the provider
    // refactor is designed to rule out).
    let e = engine();
    let reqs = generate_requests(&e.man, "squad", 3, 17); // arrival 0
    let opts = ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());
    let bulk = e.serve(&reqs, &opts).unwrap();
    assert!(bulk.oom.is_none());

    let ccfg = ContinuousConfig {
        max_in_flight: reqs.len(),
        queue_capacity: reqs.len() + 4,
        ..ContinuousConfig::default()
    };
    let cont = e.serve_continuous(&reqs, &opts, &ccfg).unwrap();
    assert!(cont.oom.is_none());
    assert_eq!(cont.rejected, 0);

    assert_eq!(bulk.tokens, cont.tokens, "token streams diverged");
    let (b, c) = (bulk.expert_stats, cont.expert_stats);
    assert_eq!(b.hits, c.hits, "cache hits diverged across modes");
    assert_eq!(b.misses, c.misses, "cache misses diverged across modes");
    assert_eq!(b.bytes_fetched, c.bytes_fetched,
               "transferred bytes diverged across modes");
    assert_eq!(b.accuracy.total, c.accuracy.total,
               "accuracy observation counts diverged");
    assert_eq!(b.accuracy.exact, c.accuracy.exact);
    assert_eq!(b.accuracy.at_least_half, c.accuracy.at_least_half);
    assert!((bulk.hit_rate - cont.hit_rate).abs() < 1e-12,
            "hit rate diverged: {} vs {}", bulk.hit_rate, cont.hit_rate);
    // The outcome's headline fields are the ledger's, not a second set
    // of counters.
    assert!((bulk.hit_rate - b.hit_rate()).abs() < 1e-12);
    assert_eq!(bulk.accuracy.total, b.accuracy.total);
}

#[test]
fn threaded_prefetch_matches_sync_provider_bit_exactly() {
    // The PrefetchWorker thread only changes *when* weights are
    // staged, never *which* weights: tokens, routing paths and the
    // virtual-time schedule must be identical with and without it.
    let e = engine();
    let reqs = generate_requests(&e.man, "orca", 3, 23);
    let threaded = ServeOptions::new(PolicyKind::DuoServe,
                                     DeviceProfile::a6000());
    assert_eq!(threaded.staging, StagingMode::Threaded);
    let mut sync = ServeOptions::new(PolicyKind::DuoServe,
                                     DeviceProfile::a6000());
    sync.staging = StagingMode::Sync;

    let a = e.serve(&reqs, &threaded).unwrap();
    let b = e.serve(&reqs, &sync).unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "staging mode changed the tokens");
    for (ea, eb) in a.episodes.iter().zip(&b.episodes) {
        assert_eq!(ea.steps, eb.steps, "staging mode changed the routing");
    }
    assert_eq!(a.summary.makespan, b.summary.makespan,
               "staging mode leaked into virtual time");
    assert_eq!(a.expert_stats.hits, b.expert_stats.hits);
    assert_eq!(a.expert_stats.misses, b.expert_stats.misses);

    // Acquire accounting is exhaustive: every functional fetch is
    // either staged or synchronous, and the total is mode-invariant.
    assert_eq!(a.expert_stats.acquires(), b.expert_stats.acquires(),
               "total weight acquisitions diverged");
    assert_eq!(b.expert_stats.staged_acquires, 0,
               "sync provider must never report staged acquires");
    assert_eq!(b.expert_stats.prefetch_hints, 0,
               "sync provider must ignore prefetch hints");
    assert!(a.expert_stats.prefetch_hints > 0,
            "threaded provider received no staging hints");
}

#[test]
fn expert_fanout_keeps_the_ledger_identical_to_serial() {
    // Threaded expert-group execution pre-acquires every group's
    // weights on the caller thread (in serial order) before fanning
    // out, so the ledger must be *exactly* the serial ledger — every
    // counter, not just totals. Sync staging makes the
    // staged/sync-acquire split deterministic too, so the assertion
    // can be complete.
    let e = engine();
    let reqs = generate_requests(&e.man, "orca", 4, 41);
    let mut serial = ServeOptions::new(PolicyKind::DuoServe,
                                       DeviceProfile::a6000());
    serial.staging = StagingMode::Sync;
    serial.expert_fanout = false;
    let mut fanned = serial.clone();
    fanned.expert_fanout = true;

    let a = e.serve(&reqs, &serial).unwrap();
    let b = e.serve(&reqs, &fanned).unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "expert fan-out changed the tokens");

    let (sa, sb) = (a.expert_stats, b.expert_stats);
    assert_eq!(sa.hits, sb.hits, "fan-out changed cache hits");
    assert_eq!(sa.misses, sb.misses, "fan-out changed cache misses");
    assert_eq!(sa.bytes_fetched, sb.bytes_fetched,
               "fan-out changed transferred bytes");
    assert_eq!(sa.staged_acquires, sb.staged_acquires,
               "fan-out changed staged acquires");
    assert_eq!(sa.sync_acquires, sb.sync_acquires,
               "fan-out changed sync acquires");
    assert_eq!(sa.prefetch_hints, sb.prefetch_hints,
               "fan-out changed prefetch hints");
    assert_eq!(sa.accuracy.total, sb.accuracy.total);
    assert_eq!(sa.accuracy.exact, sb.accuracy.exact);
    assert_eq!(sa.accuracy.at_least_half, sb.accuracy.at_least_half);
}

#[test]
fn no_overlap_ablation_forces_the_sync_provider() {
    use duoserve::coordinator::engine::Ablation;
    let e = engine();
    let reqs = generate_requests(&e.man, "squad", 1, 7);
    let opts = ServeOptions::ablated(PolicyKind::DuoServe,
                                     DeviceProfile::a6000(),
                                     Ablation::NoOverlap);
    let out = e.serve(&reqs, &opts).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.expert_stats.staged_acquires, 0,
               "NoOverlap must serve through the synchronous provider");
    assert_eq!(out.expert_stats.prefetch_hints, 0);
    assert!(out.expert_stats.sync_acquires > 0);
}

#[test]
fn worker_stages_the_host_pools_exact_tensors() {
    let dir = duoserve::testkit::ensure_tiny();
    let man = Manifest::load(&dir, "mixtral-tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    let pool = Arc::new(HostPool::load(&man, &rt).unwrap());
    let w = PrefetchWorker::spawn(pool.clone());
    let keys: Vec<ExpertKey> =
        (0..man.sim.n_experts).map(|e| ExpertKey::routed(0, e)).collect();
    w.stage(keys.clone());
    w.drain();
    assert_eq!(w.staged_len(), keys.len());
    for key in keys {
        let staged = w.staged_get(key).expect("key not staged after drain");
        let direct = pool.expert_tensors(key).unwrap();
        assert!(Arc::ptr_eq(&staged, &direct),
                "worker delivered a diverging copy for {key:?}");
    }
    // retire drops staged layers below the watermark
    w.retire_below(1);
    w.drain();
    assert_eq!(w.staged_len(), 0);
}

#[test]
fn provider_acquire_counts_staged_and_sync_paths() {
    let dir = duoserve::testkit::ensure_tiny();
    let man = Manifest::load(&dir, "mixtral-tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    let pool = Arc::new(HostPool::load(&man, &rt).unwrap());
    let mut p = StagedExpertProvider::new(pool.clone(),
                                          DeviceExpertCache::new(2, 2), 64,
                                          StagingMode::Threaded);
    let key = ExpertKey::routed(1, 0);
    let direct = pool.expert_tensors(key).unwrap();

    // cold acquire: synchronous fallback, same tensors
    let a = p.acquire(key).unwrap();
    assert!(Arc::ptr_eq(&a, &direct));

    // staged acquire: hint -> worker delivery -> staged-table hit
    p.prefetch(&[key]);
    p.worker().unwrap().drain();
    let b = p.acquire(key).unwrap();
    assert!(Arc::ptr_eq(&b, &direct));

    let s = p.stats();
    assert_eq!(s.sync_acquires, 1);
    assert_eq!(s.staged_acquires, 1);
    assert_eq!(s.prefetch_hints, 1);
}

/// Every ledger counter, compared field by field.
fn assert_stats_eq(a: &ExpertStats, b: &ExpertStats, what: &str) {
    assert_eq!(a.hits, b.hits, "{what}: hits diverged");
    assert_eq!(a.misses, b.misses, "{what}: misses diverged");
    assert_eq!(a.bytes_fetched, b.bytes_fetched,
               "{what}: transferred bytes diverged");
    assert_eq!(a.staged_acquires, b.staged_acquires,
               "{what}: staged acquires diverged");
    assert_eq!(a.sync_acquires, b.sync_acquires,
               "{what}: sync acquires diverged");
    assert_eq!(a.prefetch_hints, b.prefetch_hints,
               "{what}: prefetch hints diverged");
    assert_eq!(a.staging_poisoned, b.staging_poisoned,
               "{what}: poisoned-lock counts diverged");
    assert_eq!(a.accuracy.total, b.accuracy.total,
               "{what}: accuracy observations diverged");
    assert_eq!(a.accuracy.exact, b.accuracy.exact);
    assert_eq!(a.accuracy.at_least_half, b.accuracy.at_least_half);
}

#[test]
fn single_shard_serving_is_bit_identical_to_unsharded() {
    // `--shards 1` routes everything through ShardedExpertProvider's
    // dispatch, hashing and aggregation paths, so this is the
    // end-to-end proof that the sharding layer is a pure pass-through:
    // tokens, routing, virtual time and *every* ledger counter must
    // match the legacy provider exactly. Sync staging keeps the
    // staged/sync acquire split deterministic so the comparison can
    // be complete.
    let e = engine();
    let reqs = generate_requests(&e.man, "squad", 3, 29);
    let mut flat = ServeOptions::new(PolicyKind::DuoServe,
                                     DeviceProfile::a6000());
    flat.staging = StagingMode::Sync;
    assert_eq!(flat.shards, None, "unsharded must be the default");
    let mut one = flat.clone();
    one.shards = Some(1);

    let a = e.serve(&reqs, &flat).unwrap();
    let b = e.serve(&reqs, &one).unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "sharding layer changed the tokens");
    for (ea, eb) in a.episodes.iter().zip(&b.episodes) {
        assert_eq!(ea.steps, eb.steps, "sharding layer changed the routing");
    }
    assert_eq!(a.summary.makespan, b.summary.makespan,
               "sharding layer leaked into virtual time");
    assert_eq!(a.peak_bytes, b.peak_bytes,
               "sharding layer changed the memory profile");
    assert_stats_eq(&a.expert_stats, &b.expert_stats, "N=1 parity");

    // The sharded outcome also reports its per-shard view: one shard,
    // carrying the whole aggregate, perfectly balanced.
    assert_eq!(b.shard_stats.len(), 1);
    assert_eq!(b.shard_resident.len(), 1);
    assert_stats_eq(&b.expert_stats, &b.shard_stats[0],
                    "aggregate vs only shard");
    assert_eq!(b.shard_balance, 1.0);
    // The unsharded outcome reports the same shape (one ledger).
    assert_eq!(a.shard_stats.len(), 1);
    assert_eq!(a.shard_balance, 1.0);
}

#[test]
fn multi_shard_serving_is_deterministic_and_aggregates_exactly() {
    // Same seed, same placement: two runs must agree on tokens,
    // virtual time and the per-shard ledgers, and the aggregate
    // ledger must be exactly the fold of the shard ledgers.
    let e = engine();
    let reqs = generate_requests(&e.man, "orca", 3, 31);
    let mut opts = ServeOptions::new(PolicyKind::DuoServe,
                                     DeviceProfile::a6000());
    opts.staging = StagingMode::Sync;
    opts.shards = Some(3);
    opts.placement = Placement::ReplicateHot;

    let a = e.serve(&reqs, &opts).unwrap();
    let b = e.serve(&reqs, &opts).unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "sharded run is not deterministic");
    assert_eq!(a.summary.makespan, b.summary.makespan);
    assert_eq!(a.shard_stats.len(), 3);
    assert_eq!(a.shard_resident, b.shard_resident);
    assert_eq!(a.shard_balance, b.shard_balance);
    for (i, (sa, sb)) in a.shard_stats.iter()
        .zip(&b.shard_stats).enumerate() {
        assert_stats_eq(sa, sb, &format!("shard {i} rerun"));
    }

    // Aggregate = fold of the shards, counter by counter.
    let mut folded = ExpertStats::default();
    for s in &a.shard_stats {
        folded.absorb(s);
    }
    assert_stats_eq(&a.expert_stats, &folded, "aggregate vs shard fold");
    assert!(a.shard_balance > 0.0 && a.shard_balance <= 1.0,
            "balance must be a min/max ratio, got {}", a.shard_balance);
}

#[test]
fn poisoned_staging_lock_degrades_to_sync_without_changing_tokens() {
    // A panicked staging thread poisons the staged-table mutex. The
    // provider must treat that as a permanent staging miss — counted,
    // never unwrapped — and serve every acquire through the
    // synchronous host-pool fallback with bit-identical results.
    let e = engine();
    let reqs = generate_requests(&e.man, "squad", 2, 37);
    let mut sync = ServeOptions::new(PolicyKind::DuoServe,
                                     DeviceProfile::a6000());
    sync.staging = StagingMode::Sync;
    let mut faulty = ServeOptions::new(PolicyKind::DuoServe,
                                       DeviceProfile::a6000());
    assert_eq!(faulty.staging, StagingMode::Threaded);
    faulty.staging_fault = true;

    let a = e.serve(&reqs, &sync).unwrap();
    let b = e.serve(&reqs, &faulty).unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "poisoned staging changed the tokens");
    for (ea, eb) in a.episodes.iter().zip(&b.episodes) {
        assert_eq!(ea.steps, eb.steps,
                   "poisoned staging changed the routing");
    }
    assert_eq!(a.summary.makespan, b.summary.makespan,
               "poisoned staging leaked into virtual time");
    let (sa, sb) = (a.expert_stats, b.expert_stats);
    assert_eq!(sa.hits, sb.hits);
    assert_eq!(sa.misses, sb.misses);
    assert_eq!(sa.bytes_fetched, sb.bytes_fetched);
    // Degradation is visible in the ledger, not hidden.
    assert_eq!(sb.staged_acquires, 0,
               "nothing can be staged through a poisoned lock");
    assert!(sb.staging_poisoned > 0,
            "poisoned-lock fallbacks must be counted");
    assert_eq!(sb.staging_poisoned, sb.sync_acquires,
               "every acquire must have fallen back synchronously");
    assert_eq!(sa.staging_poisoned, 0,
               "healthy runs must never report poisoned locks");
}

#[test]
fn provider_survives_a_poisoned_staging_table() {
    // Unit-level version of the degradation contract: after the lock
    // is poisoned, staged lookups report empty, hints are still
    // counted, and acquire falls back to the host pool's exact
    // tensors while tallying the poisoned observation.
    let dir = duoserve::testkit::ensure_tiny();
    let man = Manifest::load(&dir, "mixtral-tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    let pool = Arc::new(HostPool::load(&man, &rt).unwrap());
    let mut p = StagedExpertProvider::new(pool.clone(),
                                          DeviceExpertCache::new(2, 2), 64,
                                          StagingMode::Threaded);
    p.poison_staging_for_test();
    let key = ExpertKey::routed(2, 1);

    p.prefetch(&[key]);
    let w = p.worker().unwrap();
    w.drain();
    assert_eq!(w.staged_len(), 0, "poisoned table must read as empty");
    assert!(w.staged_get(key).is_none());

    let got = p.acquire(key).unwrap();
    let direct = pool.expert_tensors(key).unwrap();
    assert!(Arc::ptr_eq(&got, &direct),
            "fallback must deliver the host pool's exact tensors");
    let s = p.stats();
    assert_eq!(s.staging_poisoned, 1);
    assert_eq!(s.sync_acquires, 1);
    assert_eq!(s.staged_acquires, 0);
    assert_eq!(s.prefetch_hints, 1);
}
