//! Engine-level tests of the QoS priority-class machinery: the
//! no-classes default (and an all-one-class run) must stay
//! bit-identical to the class-blind scheduler, the class-aware
//! overload valves must split their counts into the right per-class
//! slots, and an interactive admission must preempt a batch request's
//! pending prefill chunks at the serving-loop level — never changing
//! any request's tokens.

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ClassPolicy, ContinuousConfig, Engine,
                            ServeOptions, ServeOutcome, ServerEvent};
use duoserve::metrics::ClassRobustness;
use duoserve::workload::{assign_arrivals, generate_requests,
                         ArrivalProcess, PriorityClass, Request};

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

fn short_requests(engine: &Engine, n: usize, seed: u64) -> Vec<Request> {
    let mut reqs = generate_requests(&engine.man, "squad", n, seed);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.n_decode = 3 + (i % 3);
    }
    reqs
}

fn opts() -> ServeOptions {
    ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000())
}

/// Everything in two outcomes that the "classes off/all-one-class must
/// be bit-identical" acceptance criterion covers: the event schedule,
/// the tokens, every per-request metric, and every ledger counter.
fn assert_bit_identical(blind: &ServeOutcome, classed: &ServeOutcome) {
    assert!(blind.oom.is_none() && classed.oom.is_none());
    assert_eq!(blind.events, classed.events,
               "classes reordered the event schedule");
    assert_eq!(blind.tokens, classed.tokens,
               "classes changed the function");
    assert_eq!(format!("{:?}", blind.metrics),
               format!("{:?}", classed.metrics),
               "per-request metrics diverged");
    // ExpertStats carries no PartialEq (it is a live ledger, not a
    // value type); its Debug form covers every counter.
    assert_eq!(format!("{:?}", blind.expert_stats),
               format!("{:?}", classed.expert_stats),
               "expert-path accounting diverged");
    assert_eq!(blind.rejected, classed.rejected);
    assert_eq!(blind.expired, classed.expired);
    assert_eq!(blind.shed, classed.shed);
    assert_eq!(blind.cancelled, classed.cancelled);
    assert_eq!(blind.summary.robustness.preempted, 0);
    assert_eq!(classed.summary.robustness.preempted, 0,
               "a single-class run has nothing to preempt");
    // The aggregate Summary must agree except for the two class-only
    // attachments (the per-class splits and latency tails).
    let mut norm = classed.summary.clone();
    norm.class_latency = None;
    norm.robustness.by_class = Default::default();
    assert_eq!(format!("{:?}", blind.summary), format!("{norm:?}"),
               "summary diverged beyond the class-only attachments");
}

#[test]
fn classed_all_standard_run_matches_class_blind_bit_for_bit() {
    // The dedicated default-parity check: the same open-loop workload
    // served with `classes: None` and with classes *on* but every
    // request Standard (one non-empty queue makes weighted round-robin
    // degenerate to FIFO) must produce the identical run.
    let e = engine();
    let mk = || {
        let mut reqs = short_requests(&e, 6, 17);
        assign_arrivals(&mut reqs,
                        &ArrivalProcess::Poisson { rate: 3.0, seed: 9 });
        reqs
    };
    let base = ContinuousConfig { max_in_flight: 2, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    let classed_cfg = ContinuousConfig { classes: Some(ClassPolicy::default()),
                                         ..base.clone() };
    let blind = e.serve_continuous(&mk(), &opts(), &base).unwrap();
    let classed = e.serve_continuous(&mk(), &opts(), &classed_cfg).unwrap();
    assert_bit_identical(&blind, &classed);

    // The blind run attaches no per-class data at all; the classed run
    // reports its (degenerate, all-Standard) split.
    assert!(blind.summary.class_latency.is_none());
    assert_eq!(blind.summary.robustness.by_class,
               [ClassRobustness::default(); 3]);
    let cl = classed.summary.class_latency
        .expect("classes on: per-class latency tails must be attached");
    assert_eq!(cl[0].n_requests, 0);
    assert_eq!(cl[1].n_requests, classed.metrics.len());
    assert_eq!(cl[2].n_requests, 0);
}

#[test]
fn class_aware_valves_stay_bit_identical_and_count_in_the_standard_slot() {
    // Same parity under active overload valves: an 8-request burst
    // into a shed threshold of 3 and a (virtually) immediate queue
    // deadline sheds and expires identically with classes on — and the
    // classed run books every degradation count in the Standard slot.
    let e = engine();
    let mk = || {
        let mut reqs = short_requests(&e, 8, 23);
        assign_arrivals(&mut reqs, &ArrivalProcess::Closed);
        reqs
    };
    let base = ContinuousConfig { max_in_flight: 1, queue_capacity: 8,
                                  shed_threshold: 3, queue_deadline: 1e-3,
                                  ..ContinuousConfig::default() };
    let classed_cfg = ContinuousConfig { classes: Some(ClassPolicy::default()),
                                         ..base.clone() };
    let blind = e.serve_continuous(&mk(), &opts(), &base).unwrap();
    let classed = e.serve_continuous(&mk(), &opts(), &classed_cfg).unwrap();
    assert_bit_identical(&blind, &classed);
    assert!(classed.shed > 0, "burst never tripped the shed valve");
    assert!(classed.expired > 0, "deadline never expired a queued request");

    assert_eq!(blind.summary.robustness.by_class,
               [ClassRobustness::default(); 3]);
    let by_class = classed.summary.robustness.by_class;
    assert_eq!(by_class[0], ClassRobustness::default());
    assert_eq!(by_class[2], ClassRobustness::default());
    assert_eq!(by_class[1],
               ClassRobustness { expired: classed.expired,
                                 shed: classed.shed,
                                 cancelled: classed.cancelled,
                                 preempted: 0 },
               "all-Standard degradation must land in the Standard slot");
}

#[test]
fn interactive_admission_preempts_batch_prefill_at_engine_level() {
    // A batch request with a near-max prompt is mid-chunked-prefill
    // when an interactive request arrives: the serving loop must
    // reorder the pending chunks (one Preempted event, batch victim),
    // finish the interactive prefill first, and still emit exactly the
    // tokens a class-blind run produces.
    let e = engine();
    let mut reqs = short_requests(&e, 2, 41);
    while reqs[0].prompt.len() < e.man.sim.max_seq - 4 {
        let t = reqs[0].prompt[reqs[0].prompt.len() % 5];
        reqs[0].prompt.push(t);
    }
    reqs[0].n_decode = 4;
    reqs[0].class = PriorityClass::Batch;
    reqs[1].prompt.truncate(8);
    reqs[1].n_decode = 6;
    reqs[1].class = PriorityClass::Interactive;

    // Place the interactive arrival squarely inside the batch prefill
    // (chunking can only lengthen it relative to the solo probe).
    let probe = e.serve(&reqs[..1], &opts()).unwrap();
    assert!(probe.oom.is_none());
    reqs[0].arrival = 0.0;
    reqs[1].arrival = probe.metrics[0].ttft * 0.5;

    let mut o = opts();
    o.prefill_chunk = Some(4);
    let base = ContinuousConfig { max_in_flight: 2, queue_capacity: 8,
                                  ..ContinuousConfig::default() };
    let classed_cfg = ContinuousConfig { classes: Some(ClassPolicy::default()),
                                         ..base.clone() };
    let blind = e.serve_continuous(&reqs, &o, &base).unwrap();
    let classed = e.serve_continuous(&reqs, &o, &classed_cfg).unwrap();
    assert!(blind.oom.is_none() && classed.oom.is_none());
    assert_eq!(blind.tokens, classed.tokens,
               "preemption must never change the tokens");

    // The reorder happened, was recorded, and was counted to the
    // batch victim's slot.
    assert!(classed.events.iter().any(|ev| matches!(
                ev, ServerEvent::Preempted { req: 0, by: 1, .. })),
            "no Preempted event for the deferred batch prefill");
    let rb = &classed.summary.robustness;
    assert_eq!(rb.preempted, 1);
    assert_eq!(rb.by_class[2].preempted, 1, "victim is the batch class");
    assert_eq!(rb.by_class[0].preempted, 0);
    assert_eq!(blind.summary.robustness.preempted, 0);

    // The interactive prefill finishes first despite arriving second
    // (in the blind run the batch prompt's chunks drain first).
    let done_at = |out: &ServeOutcome, want: usize| -> usize {
        out.events.iter().position(|ev| matches!(
            ev, ServerEvent::PrefillDone { req, .. } if *req == want))
            .expect("missing PrefillDone")
    };
    assert!(done_at(&classed, 1) < done_at(&classed, 0),
            "interactive prefill should complete before the batch one");
    assert!(done_at(&blind, 0) < done_at(&blind, 1),
            "class-blind FIFO should finish the batch prefill first");

    // Both requests served; the per-class tails cover one request each.
    let cl = classed.summary.class_latency.expect("classes were on");
    assert_eq!(cl[0].n_requests, 1);
    assert_eq!(cl[1].n_requests, 0);
    assert_eq!(cl[2].n_requests, 1);
    assert!(cl[0].p95_ttft > 0.0 && cl[2].p95_ttft > 0.0);
}
