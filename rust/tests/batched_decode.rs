//! Batched lockstep decode parity suite: the "one GEMM per layer
//! across the active batch" hot path must emit **bit-identical**
//! per-request token streams (and routing, and virtual-time schedules)
//! to the row-at-a-time fallback (`DUOSERVE_FORCE_ROWWISE=1` /
//! `ServeOptions::force_rowwise`), across batch sizes, ragged request
//! lifetimes (requests leaving at different steps), mid-run joins
//! under `serve_continuous`, and with the threaded expert fan-out
//! forced on and off.

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ContinuousConfig, Engine, ServeOptions,
                            ServeOutcome};
use duoserve::workload::{generate_requests, Request};

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

fn opts(rowwise: bool, fanout: bool) -> ServeOptions {
    let mut o = ServeOptions::new(PolicyKind::DuoServe,
                                  DeviceProfile::a6000());
    // set explicitly: the env-default test below mutates the
    // process environment, and tests in this binary run in parallel
    o.force_rowwise = rowwise;
    o.expert_fanout = fanout;
    o
}

fn assert_bit_identical(batched: &ServeOutcome, rowwise: &ServeOutcome,
                        what: &str) {
    assert!(batched.oom.is_none() && rowwise.oom.is_none(), "{what}: OOM");
    assert_eq!(batched.tokens, rowwise.tokens,
               "{what}: token streams diverged");
    for (i, (eb, er)) in
        batched.episodes.iter().zip(&rowwise.episodes).enumerate()
    {
        assert_eq!(eb.steps, er.steps, "{what}: request {i} routing diverged");
    }
    // the virtual-time schedule is shared code — makespan must agree
    // exactly, not approximately
    assert_eq!(batched.summary.makespan, rowwise.summary.makespan,
               "{what}: virtual time diverged");
    assert_eq!(batched.expert_stats.hits, rowwise.expert_stats.hits,
               "{what}: cache hits diverged");
    assert_eq!(batched.expert_stats.misses, rowwise.expert_stats.misses,
               "{what}: cache misses diverged");
}

#[test]
fn batched_matches_rowwise_across_batch_sizes_and_ragged_exits() {
    let e = engine();
    for &b in &[1usize, 3, 8] {
        let mut reqs = generate_requests(&e.man, "squad", b, 7 + b as u64);
        // ragged lifetimes: every request decodes a different number
        // of tokens, so the active batch shrinks step by step and the
        // gather/scatter runs over every intermediate batch size
        for (i, r) in reqs.iter_mut().enumerate() {
            r.n_decode = 3 + i;
        }
        for fanout in [false, true] {
            let rowwise = e.serve(&reqs, &opts(true, fanout)).unwrap();
            let batched = e.serve(&reqs, &opts(false, fanout)).unwrap();
            assert_bit_identical(&batched, &rowwise,
                                 &format!("b={b} fanout={fanout}"));
            // the decode-throughput summary must be populated and
            // identical (same tokens, same virtual busy time)
            assert!(batched.summary.decode_tokens > 0);
            assert_eq!(batched.summary.decode_tokens,
                       rowwise.summary.decode_tokens);
            assert_eq!(batched.summary.decode_time,
                       rowwise.summary.decode_time);
        }
    }
}

#[test]
fn continuous_ragged_join_and_leave_matches_rowwise() {
    // Staggered arrivals under a max-in-flight budget: requests join
    // the running batch between decode iterations and leave at
    // different steps (varying n_decode), so batch membership changes
    // nearly every step — the stress case for the batched
    // gather/scatter and the per-request KV ownership transfer.
    let e = engine();
    let mut reqs: Vec<Request> = generate_requests(&e.man, "orca", 8, 23);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.arrival = i as f64 * 0.003;
        r.n_decode = 2 + (i % 4);
    }
    let ccfg = ContinuousConfig { max_in_flight: 3, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    for fanout in [false, true] {
        let rowwise =
            e.serve_continuous(&reqs, &opts(true, fanout), &ccfg).unwrap();
        let batched =
            e.serve_continuous(&reqs, &opts(false, fanout), &ccfg).unwrap();
        assert_eq!(batched.rejected, rowwise.rejected);
        assert_bit_identical(&batched, &rowwise,
                             &format!("continuous fanout={fanout}"));
        // identical virtual time implies identical admission schedules;
        // make that explicit
        assert_eq!(batched.events, rowwise.events,
                   "continuous fanout={fanout}: event schedules diverged");
    }
}

#[test]
fn batched_decode_matches_frozen_goldens() {
    // The batched path is the default: it must still reproduce the
    // frozen golden token streams exactly (goldens were recorded by
    // the row-at-a-time engine).
    let e = engine();
    let path = e.man.resolve(&e.man.goldens);
    let text = std::fs::read_to_string(&path).unwrap();
    let goldens = duoserve::util::Json::parse(&text).unwrap();
    let goldens = goldens.as_arr().unwrap();
    assert!(!goldens.is_empty());
    for (i, g) in goldens.iter().enumerate() {
        let req = Request {
            req_id: i,
            dataset: g.get("dataset").unwrap().as_str().unwrap().to_string(),
            cluster: 0,
            prompt: g.get("prompt").unwrap().i32_vec().unwrap(),
            n_decode: g.get("n_decode").unwrap().as_usize().unwrap(),
            arrival: 0.0,
            class: Default::default(),
        };
        let out =
            e.serve(std::slice::from_ref(&req), &opts(false, true)).unwrap();
        let want: Vec<i32> = g.get("tokens").unwrap().i32_vec().unwrap();
        assert_eq!(out.tokens[0], want, "golden {i} diverged (batched path)");
    }
}

#[test]
fn batched_path_is_the_default() {
    // The env parsing itself ("1" -> rowwise, "0" -> no fan-out) is
    // unit-tested in-crate through pure helpers; mutating the process
    // environment here would race with the parallel tests above.
    let o = ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());
    assert!(!o.force_rowwise, "default must be the batched decode path");
    assert!(o.expert_fanout, "default must fan expert groups out");
}

#[test]
fn decode_step_bench_is_repeatable() {
    // The micro-bench driver must do identical work every call:
    // request state (pos, token count) is rolled back after each step.
    let e = engine();
    let mut db = e.decode_step_bench(4, &opts(false, true)).unwrap();
    assert_eq!(db.batch(), 4);
    db.step().unwrap();
    db.step().unwrap();
    db.step().unwrap();
}
