//! Zero-copy regression guard for the decode hot path: a serve run —
//! prefill plus every decode step, phase-bulk and continuous — must
//! perform **zero** full-KV-cache deep copies at the literal
//! boundary. Per-step KV writes are O(d_model) per layer via
//! ownership transfer (`ArgRef::Own`); any reintroduced clone (e.g. a
//! `to_vec()` on the cache, or a shared handle forcing copy-on-write)
//! trips the `copy_stats` counters.
//!
//! This lives in its own test binary on purpose: the counters are
//! process-global, and other suites (native_parity, the runtime unit
//! tests) intentionally exercise the copy-on-write path in parallel.

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ContinuousConfig, Engine, ServeOptions};
use duoserve::runtime::copy_stats;
use duoserve::workload::{assign_arrivals, generate_requests,
                         ArrivalProcess};

#[test]
fn serving_performs_zero_kv_cache_deep_copies() {
    let dir = duoserve::testkit::ensure_tiny();
    let engine = Engine::load(&dir, "mixtral-tiny").unwrap();
    let opts =
        ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());

    // phase-bulk: sequential prefills + lockstep batched decode
    let reqs = generate_requests(&engine.man, "squad", 3, 11);
    copy_stats::reset();
    let out = engine.serve(&reqs, &opts).unwrap();
    assert!(out.oom.is_none());
    assert!(out.tokens.iter().all(|t| !t.is_empty()),
            "serve generated no tokens — the hot path never ran");
    assert_eq!(
        copy_stats::deep_copies(), 0,
        "phase-bulk serve deep-copied {} tensors ({} elements) at the \
         literal boundary; the decode hot path must be zero-copy",
        copy_stats::deep_copies(), copy_stats::deep_copy_elems());

    // continuous: open-loop arrivals joining the running batch
    // mid-stream (the KV-aliasing stress case)
    let mut reqs = generate_requests(&engine.man, "orca", 4, 13);
    assign_arrivals(&mut reqs,
                    &ArrivalProcess::Poisson { rate: 3.0, seed: 5 });
    let ccfg = ContinuousConfig { max_in_flight: 2, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    copy_stats::reset();
    let out = engine.serve_continuous(&reqs, &opts, &ccfg).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(
        copy_stats::deep_copies(), 0,
        "continuous serve deep-copied {} tensors ({} elements) at the \
         literal boundary",
        copy_stats::deep_copies(), copy_stats::deep_copy_elems());

    // paged KV + prefix cache: the page-sharing path (Arc-backed page
    // clones, full-page-only reuse) must also be zero-copy — shared
    // pages sit strictly before the write cursor, so no COW fork and
    // no deep copy may fire even when a request decodes on top of
    // pages another request wrote
    let mut reqs = generate_requests(&engine.man, "squad", 1, 17);
    let mut twin = reqs[0].clone();
    twin.req_id = 1;
    reqs.push(twin);
    let mut opts =
        ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());
    opts.kv_page = Some(2);
    opts.prefill_chunk = Some(2);
    opts.prefix_cache = true;
    copy_stats::reset();
    let out = engine.serve(&reqs, &opts).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.summary.kv_paging.prefix_hits, 1,
               "the twin request must reuse the first prompt's pages");
    assert_eq!(
        copy_stats::deep_copies(), 0,
        "page-sharing serve deep-copied {} tensors ({} elements); \
         prefix reuse must stay zero-copy",
        copy_stats::deep_copies(), copy_stats::deep_copy_elems());
}
