//! Behavioural tests of the event-driven continuous-batching serving
//! loop: schedule determinism, FIFO fairness under backlog, the
//! max-in-flight budget, prefill/decode interleaving for late
//! arrivals, admission rejections, and functional equivalence with the
//! phase-bulk mode (function and time are split — the serving
//! discipline may never change the tokens).

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ContinuousConfig, Engine, ServeOptions,
                            ServerEvent};
use duoserve::workload::{assign_arrivals, generate_requests,
                         ArrivalProcess, Request};

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

fn short_requests(engine: &Engine, n: usize, seed: u64) -> Vec<Request> {
    let mut reqs = generate_requests(&engine.man, "squad", n, seed);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.n_decode = 3 + (i % 3);
    }
    reqs
}

fn opts(policy: PolicyKind) -> ServeOptions {
    ServeOptions::new(policy, DeviceProfile::a6000())
}

#[test]
fn same_seed_gives_identical_tokens_and_schedule() {
    let e = engine();
    let ccfg = ContinuousConfig { max_in_flight: 2, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    let mk = || {
        let mut reqs = short_requests(&e, 6, 17);
        assign_arrivals(&mut reqs,
                        &ArrivalProcess::Poisson { rate: 3.0, seed: 9 });
        reqs
    };
    let a = e.serve_continuous(&mk(), &opts(PolicyKind::DuoServe), &ccfg)
        .unwrap();
    let b = e.serve_continuous(&mk(), &opts(PolicyKind::DuoServe), &ccfg)
        .unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "token streams diverged across runs");
    assert_eq!(a.events, b.events, "virtual-time schedule diverged");
    let ttfts = |out: &duoserve::coordinator::ServeOutcome| -> Vec<f64> {
        out.metrics.iter().map(|m| m.ttft).collect()
    };
    assert_eq!(ttfts(&a), ttfts(&b));
}

#[test]
fn backlog_is_served_fifo_with_distinct_queueing_delays() {
    let e = engine();
    let ccfg = ContinuousConfig { max_in_flight: 2, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    let mut reqs = short_requests(&e, 6, 23);
    assign_arrivals(&mut reqs, &ArrivalProcess::Closed);
    let out = e
        .serve_continuous(&reqs, &opts(PolicyKind::DuoServe), &ccfg)
        .unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.rejected, 0);
    assert_eq!(out.metrics.len(), reqs.len());

    // FIFO: prefills issued in arrival (= request-id) order.
    let starts: Vec<usize> = out
        .events
        .iter()
        .filter_map(|ev| match ev {
            ServerEvent::PrefillStart { req, .. } => Some(*req),
            _ => None,
        })
        .collect();
    assert_eq!(starts, (0..reqs.len()).collect::<Vec<_>>());

    // The single GPU serialises prefills, so simultaneous arrivals get
    // strictly increasing queueing delays — and TTFT is measured from
    // arrival, so it inherits that queueing component.
    let mut by_id = out.metrics.clone();
    by_id.sort_by_key(|m| m.req_id);
    assert_eq!(by_id[0].queue_delay, 0.0);
    for w in by_id.windows(2) {
        assert!(w[1].queue_delay > w[0].queue_delay,
                "queue delays not distinct/increasing: {} vs {}",
                w[0].queue_delay, w[1].queue_delay);
        assert!(w[1].ttft > w[0].ttft,
                "arrival-relative TTFT lost the queueing component");
    }
}

#[test]
fn max_in_flight_budget_never_exceeded() {
    let e = engine();
    let max_in_flight = 3;
    let ccfg = ContinuousConfig { max_in_flight, queue_capacity: 32,
                                  ..ContinuousConfig::default() };
    let mut reqs = short_requests(&e, 8, 5);
    assign_arrivals(&mut reqs,
                    &ArrivalProcess::Poisson { rate: 50.0, seed: 2 });
    let out = e
        .serve_continuous(&reqs, &opts(PolicyKind::DuoServe), &ccfg)
        .unwrap();
    assert!(out.oom.is_none());
    let mut in_flight = 0usize;
    let mut peak = 0usize;
    for ev in &out.events {
        match ev {
            ServerEvent::PrefillStart { .. } => {
                in_flight += 1;
                peak = peak.max(in_flight);
            }
            ServerEvent::Complete { .. } => {
                in_flight = in_flight.checked_sub(1).expect("negative in-flight");
            }
            ServerEvent::StepDone { batch, .. } => {
                assert!(batch.len() <= max_in_flight,
                        "decode batch {} exceeds budget", batch.len());
            }
            _ => {}
        }
    }
    assert_eq!(in_flight, 0, "requests left holding slots");
    assert!(peak <= max_in_flight, "budget exceeded: peak {peak}");
    assert_eq!(peak, max_in_flight, "test never saturated the budget");
}

#[test]
fn continuous_mode_emits_the_same_tokens_as_phase_bulk() {
    // The serving discipline owns *time* only: per-request token
    // streams must be identical between the seed phase-bulk engine and
    // the continuous loop, whatever the batch interleaving.
    let e = engine();
    let reqs = short_requests(&e, 4, 31);
    let bulk = e.serve(&reqs, &opts(PolicyKind::DuoServe)).unwrap();

    let mut open = reqs.clone();
    assign_arrivals(&mut open,
                    &ArrivalProcess::Poisson { rate: 4.0, seed: 8 });
    let ccfg = ContinuousConfig { max_in_flight: 3, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    let cont = e
        .serve_continuous(&open, &opts(PolicyKind::DuoServe), &ccfg)
        .unwrap();
    assert!(bulk.oom.is_none() && cont.oom.is_none());
    assert_eq!(bulk.tokens, cont.tokens,
               "continuous batching changed the function");
}

#[test]
fn late_arrival_prefills_while_earlier_request_is_mid_decode() {
    let e = engine();
    // Probe: request 0 alone, phase-bulk (virtual times are absolute
    // for the first request), to place request 1's arrival mid-decode.
    let mut reqs = short_requests(&e, 2, 41);
    reqs[0].n_decode = e.man.sim.max_decode;
    reqs[1].n_decode = 3;
    let probe = e
        .serve(&reqs[..1], &opts(PolicyKind::DuoServe))
        .unwrap();
    let (t_first, t_end) = (probe.metrics[0].ttft, probe.metrics[0].e2e);
    assert!(t_end > t_first);

    reqs[0].arrival = 0.0;
    reqs[1].arrival = (t_first + t_end) / 2.0;
    let ccfg = ContinuousConfig { max_in_flight: 4, queue_capacity: 8,
                                  ..ContinuousConfig::default() };
    let out = e
        .serve_continuous(&reqs, &opts(PolicyKind::DuoServe), &ccfg)
        .unwrap();
    assert!(out.oom.is_none());

    let idx_of = |pred: &dyn Fn(&ServerEvent) -> bool| -> usize {
        out.events.iter().position(|ev| pred(ev)).expect("event missing")
    };
    let prefill1 = idx_of(&|ev| matches!(ev,
        ServerEvent::PrefillDone { req: 1, .. }));
    let solo_step_before = out.events[..prefill1].iter().any(|ev| {
        matches!(ev, ServerEvent::StepDone { batch, .. } if batch == &[0])
    });
    assert!(solo_step_before,
            "request 0 should be mid-decode before request 1's prefill");
    let joint_step_after = out.events[prefill1..].iter().any(|ev| {
        matches!(ev, ServerEvent::StepDone { batch, .. }
                 if batch.contains(&0) && batch.contains(&1))
    });
    assert!(joint_step_after,
            "request 1 should join request 0's running decode batch");
    let complete0 = idx_of(&|ev| matches!(ev,
        ServerEvent::Complete { req: 0, .. }));
    assert!(prefill1 < complete0,
            "request 1's prefill should not wait for request 0 to drain");

    // Queueing delays reflect the distinct arrivals.
    let m1 = out.metrics.iter().find(|m| m.req_id == 1).unwrap();
    assert!(m1.arrival > 0.0);
    assert!(m1.ttft < t_first + t_end,
            "late arrival waited for a full phase drain");
}

#[test]
fn admission_queue_rejections_are_counted_and_excluded() {
    let e = engine();
    let ccfg = ContinuousConfig { max_in_flight: 1, queue_capacity: 2,
                                  ..ContinuousConfig::default() };
    let mut reqs = short_requests(&e, 8, 3);
    assign_arrivals(&mut reqs, &ArrivalProcess::Closed);
    let out = e
        .serve_continuous(&reqs, &opts(PolicyKind::DuoServe), &ccfg)
        .unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.rejected, 6, "capacity-2 queue under an 8-burst");
    assert_eq!(out.metrics.len(), 2, "rejected requests must not report QoS");
    let rejected_events = out
        .events
        .iter()
        .filter(|ev| matches!(ev, ServerEvent::Rejected { .. }))
        .count();
    assert_eq!(rejected_events as u64, out.rejected);
    // Rejected requests produced no tokens.
    for m in &out.metrics {
        assert!(m.tokens_out > 0);
    }
    for (i, toks) in out.tokens.iter().enumerate() {
        if i >= 2 {
            assert!(toks.is_empty(), "rejected request {i} generated tokens");
        }
    }
}
