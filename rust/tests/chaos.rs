//! Chaos suite: fault injection may bend *time*, never *function*.
//!
//! Every test here drives the serving loop under a [`FaultPlan`] and
//! checks the degradation contract: no panic under any plan, token
//! streams bit-identical to the fault-free run, SLOs degrade
//! monotonically with fault severity, bounded retry/failover instead
//! of dead-ends, and full recovery once fault windows close. The
//! deadline/shedding tests pin the request-lifecycle half: overload is
//! shed at the door, stale queue entries expire, and in-flight
//! requests past their hard deadline are cancelled with KV released.

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ClassPolicy, ContinuousConfig, Engine,
                            ServeOptions, ServerEvent};
use duoserve::faults::{FaultPlan, FetchFail, LinkSel, LinkSlow,
                       ShardOutage, Window};
use duoserve::metrics::{slo_attainment_for_class, SloSpec};
use duoserve::util::Rng;
use duoserve::workload::{assign_arrivals, generate_requests,
                         ArrivalProcess, PriorityClass, Request};

fn engine() -> Engine {
    let dir = duoserve::testkit::ensure_tiny();
    Engine::load(&dir, "mixtral-tiny").unwrap()
}

fn short_requests(engine: &Engine, n: usize, seed: u64) -> Vec<Request> {
    let mut reqs = generate_requests(&engine.man, "squad", n, seed);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.n_decode = 3 + (i % 3);
    }
    reqs
}

fn opts(policy: PolicyKind) -> ServeOptions {
    ServeOptions::new(policy, DeviceProfile::a6000())
}

const ALWAYS: Window = Window { start: 0.0, end: f64::INFINITY };

#[test]
fn active_but_empty_plan_is_bit_identical_to_no_plan() {
    // `--faults none` maps to `None` and runs the untouched code path
    // by construction; the stronger claim is that an *active* plan
    // with no clauses also cannot move the schedule: slow factor is
    // exactly 1.0 and no attempt ever fails.
    let e = engine();
    let ccfg = ContinuousConfig { max_in_flight: 2, queue_capacity: 16,
                                  ..ContinuousConfig::default() };
    let mk = || {
        let mut reqs = short_requests(&e, 6, 17);
        assign_arrivals(&mut reqs,
                        &ArrivalProcess::Poisson { rate: 3.0, seed: 9 });
        reqs
    };
    let base_opts = opts(PolicyKind::DuoServe);
    let mut empty_opts = base_opts.clone();
    empty_opts.faults = Some(FaultPlan::default());
    assert!(empty_opts.faults.as_ref().unwrap().is_empty());

    let a = e.serve_continuous(&mk(), &base_opts, &ccfg).unwrap();
    let b = e.serve_continuous(&mk(), &empty_opts, &ccfg).unwrap();
    assert!(a.oom.is_none() && b.oom.is_none());
    assert_eq!(a.tokens, b.tokens, "empty plan changed the function");
    assert_eq!(a.events, b.events, "empty plan moved the schedule");
    assert_eq!(a.summary.makespan, b.summary.makespan);
    assert_eq!(format!("{:?}", a.expert_stats),
               format!("{:?}", b.expert_stats),
               "empty plan perturbed the expert ledger");
    assert_eq!(format!("{:?}", a.summary.robustness),
               format!("{:?}", b.summary.robustness));
}

#[test]
fn fetch_failures_retry_with_backoff_then_degrade_to_success() {
    let e = engine();
    let reqs = short_requests(&e, 4, 29);
    let base_opts = opts(PolicyKind::DuoServe);
    let base = e.serve(&reqs, &base_opts).unwrap();

    // Every attempt fails; bounded retries must still land every
    // fetch (the final attempt completes as a slowed success).
    let mut faulty_opts = base_opts.clone();
    let mut plan = FaultPlan::default();
    plan.fetch_fails.push(FetchFail {
        prob: 1.0,
        link: LinkSel::All,
        window: ALWAYS,
    });
    faulty_opts.faults = Some(plan);
    let out = e.serve(&reqs, &faulty_opts).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.tokens, base.tokens, "retries changed the function");
    assert!(out.expert_stats.fetch_retries > 0,
            "sure-fail plan produced no retries");
    assert!(out.summary.makespan > base.summary.makespan,
            "retry/backoff comm ops did not cost virtual time");
    assert_eq!(out.summary.robustness.fetch_retries,
               out.expert_stats.fetch_retries,
               "summary and ledger disagree on retry count");
}

#[test]
fn link_slowdowns_degrade_latency_monotonically_tokens_identical() {
    // ODF fetches experts on demand, so the host link sits on the
    // critical path: slowing it must slow the run, monotonically in
    // the factor, without touching a single token.
    let e = engine();
    let reqs = short_requests(&e, 4, 43);
    let run = |factor: f64| {
        let mut o = opts(PolicyKind::Odf);
        if factor > 1.0 {
            let mut plan = FaultPlan::default();
            plan.link_slows.push(LinkSlow {
                factor,
                link: LinkSel::All,
                window: ALWAYS,
            });
            o.faults = Some(plan);
        }
        e.serve(&reqs, &o).unwrap()
    };
    let base = run(1.0);
    let slow2 = run(2.0);
    let slow4 = run(4.0);
    assert_eq!(base.tokens, slow2.tokens);
    assert_eq!(base.tokens, slow4.tokens);
    let (m1, m2, m4) = (base.summary.makespan, slow2.summary.makespan,
                        slow4.summary.makespan);
    assert!(m2 > m1, "2x link slowdown did not slow the run");
    assert!(m4 > m2, "slowdown not monotone: 4x {m4} vs 2x {m2}");
}

#[test]
fn shard_outage_fails_over_and_recovers_mid_serve() {
    let e = engine();
    let reqs = short_requests(&e, 8, 57);
    let mut base_opts = opts(PolicyKind::DuoServe);
    base_opts.shards = Some(4);
    let base = e.serve(&reqs, &base_opts).unwrap();
    assert!(base.oom.is_none());
    let m = base.summary.makespan;
    assert!(m > 0.0);

    // Kill shard 1 for the middle third of the (fault-free) run.
    let mut faulty_opts = base_opts.clone();
    let mut plan = FaultPlan::default();
    plan.outages.push(ShardOutage {
        shard: 1,
        window: Window { start: 0.25 * m, end: 0.60 * m },
    });
    faulty_opts.faults = Some(plan);
    let out = e.serve(&reqs, &faulty_opts).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.metrics.len(), reqs.len(),
               "an outage must not lose requests");
    assert_eq!(out.tokens, base.tokens, "failover changed the function");
    assert!(out.expert_stats.failover_fetches > 0,
            "no fetch rehomed off the downed shard");

    // A near-instant outage leaves almost the whole run fault-free:
    // the cache must recover to its fault-free hit-rate.
    let mut brief_opts = base_opts.clone();
    let mut brief = FaultPlan::default();
    brief.outages.push(ShardOutage {
        shard: 1,
        window: Window { start: 0.0, end: 0.02 * m },
    });
    brief_opts.faults = Some(brief);
    let rec = e.serve(&reqs, &brief_opts).unwrap();
    assert_eq!(rec.tokens, base.tokens);
    assert!((rec.hit_rate - base.hit_rate).abs() < 0.1,
            "hit-rate did not recover after the outage cleared: \
             faulty {} vs fault-free {}", rec.hit_rate, base.hit_rate);
}

#[test]
fn worker_poison_degrades_acquires_but_keeps_tokens() {
    let e = engine();
    let reqs = short_requests(&e, 3, 61);
    let base_opts = opts(PolicyKind::DuoServe);
    let base = e.serve(&reqs, &base_opts).unwrap();

    let mut poison_opts = base_opts.clone();
    poison_opts.faults =
        Some(FaultPlan::parse("worker-poison").unwrap().unwrap());
    let out = e.serve(&reqs, &poison_opts).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.tokens, base.tokens, "poisoned worker changed tokens");
    assert!(out.expert_stats.degraded_acquires > 0,
            "poisoned staging lock did not degrade acquires");
    assert!(out.expert_stats.degraded_acquires
            <= out.expert_stats.touches());
}

#[test]
fn flash_crowd_sheds_and_expires_with_better_survivor_tail() {
    let e = engine();
    let mut reqs = short_requests(&e, 10, 11);
    assign_arrivals(&mut reqs, &ArrivalProcess::Closed);
    let base_opts = opts(PolicyKind::DuoServe);
    // Time scale: one request served alone.
    let solo = e.serve(&reqs[..1], &base_opts).unwrap();
    let scale = solo.metrics[0].e2e;
    assert!(scale > 0.0);

    // Unprotected: every request queues and is eventually served.
    let open = ContinuousConfig { max_in_flight: 1, queue_capacity: 64,
                                  ..ContinuousConfig::default() };
    let a = e.serve_continuous(&reqs, &base_opts, &open).unwrap();
    assert_eq!(a.metrics.len(), reqs.len());
    assert_eq!(a.shed + a.expired, 0);

    // Protected: shed the burst beyond 3 queued, expire queued
    // requests older than half a solo service time.
    let guarded = ContinuousConfig {
        max_in_flight: 1,
        queue_capacity: 64,
        queue_deadline: 0.5 * scale,
        shed_threshold: 3,
        ..ContinuousConfig::default()
    };
    let b = e.serve_continuous(&reqs, &base_opts, &guarded).unwrap();
    assert_eq!(b.shed, 7, "burst beyond the 3-deep queue must shed");
    assert_eq!(b.expired, 2, "queued survivors past deadline must expire");
    assert_eq!(b.rejected, 0, "shedding is policy, not queue overflow");
    assert_eq!(b.metrics.len(), 1);
    assert!(b.summary.p95_ttft < a.summary.p95_ttft,
            "shedding did not improve the survivors' tail: {} vs {}",
            b.summary.p95_ttft, a.summary.p95_ttft);
    // Events mirror the counters.
    let count = |pred: &dyn Fn(&ServerEvent) -> bool| {
        b.events.iter().filter(|ev| pred(ev)).count() as u64
    };
    assert_eq!(count(&|ev| matches!(ev, ServerEvent::Shed { .. })), b.shed);
    assert_eq!(count(&|ev| matches!(ev, ServerEvent::Expired { .. })),
               b.expired);
    assert_eq!(b.summary.robustness.shed, b.shed);
    assert_eq!(b.summary.robustness.expired, b.expired);
}

#[test]
fn hard_deadline_cancels_in_flight_and_accounts_every_request() {
    let e = engine();
    let mut reqs = short_requests(&e, 6, 13);
    assign_arrivals(&mut reqs, &ArrivalProcess::Closed);
    let base_opts = opts(PolicyKind::DuoServe);
    let solo = e.serve(&reqs[..1], &base_opts).unwrap();
    let scale = solo.metrics[0].e2e;

    let ccfg = ContinuousConfig {
        max_in_flight: 2,
        queue_capacity: 64,
        hard_deadline: 1.5 * scale,
        ..ContinuousConfig::default()
    };
    let out = e.serve_continuous(&reqs, &base_opts, &ccfg).unwrap();
    assert!(out.oom.is_none());
    assert!(out.cancelled > 0, "late in-flight requests must cancel");
    assert_eq!(out.metrics.len() + out.cancelled as usize, reqs.len(),
               "every request must end served or cancelled");
    assert_eq!(out.summary.robustness.cancelled, out.cancelled);
    // Cancelled requests were admitted (they are in-flight casualties,
    // not queue drops) and report no QoS metrics.
    let cancelled_ids: Vec<usize> = out
        .events
        .iter()
        .filter_map(|ev| match ev {
            ServerEvent::Cancelled { req, .. } => Some(*req),
            _ => None,
        })
        .collect();
    assert_eq!(cancelled_ids.len() as u64, out.cancelled);
    for id in &cancelled_ids {
        assert!(out.events.iter().any(|ev| matches!(ev,
            ServerEvent::PrefillStart { req, .. } if req == id)));
        assert!(!out.metrics.iter().any(|m| m.req_id == *id),
                "cancelled request {id} reported QoS metrics");
    }
    // Served requests still emit their full, fault-free token streams.
    let bulk = e.serve(&reqs, &base_opts).unwrap();
    for m in &out.metrics {
        assert_eq!(out.tokens[m.req_id], bulk.tokens[m.req_id],
                   "cancellation disturbed request {}", m.req_id);
    }
}

#[test]
fn class_scheduling_survives_shard_outage_under_batch_flood() {
    // Overload *and* faults at once: a t=0 batch flood with a few
    // interactive requests, served sharded while one shard dies
    // mid-run. The outage bends time (failover fetches) but never the
    // function, and the class-aware queues must still put every
    // interactive request ahead of the flood — interactive TTFT
    // attainment stays at least the batch tier's.
    let e = engine();
    let mut reqs = short_requests(&e, 10, 19);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.class = if i < 7 { PriorityClass::Batch }
                  else { PriorityClass::Interactive };
    }
    assign_arrivals(&mut reqs, &ArrivalProcess::Closed);
    let mut o = opts(PolicyKind::DuoServe);
    o.shards = Some(4);
    let ccfg = ContinuousConfig { max_in_flight: 1, queue_capacity: 16,
                                  classes: Some(ClassPolicy::default()),
                                  ..ContinuousConfig::default() };
    let base = e.serve_continuous(&reqs, &o, &ccfg).unwrap();
    assert!(base.oom.is_none());
    let m = base.summary.makespan;
    assert!(m > 0.0);

    // Kill shard 1 for the middle half of the fault-free run.
    let mut faulty = o.clone();
    let mut plan = FaultPlan::default();
    plan.outages.push(ShardOutage {
        shard: 1,
        window: Window { start: 0.2 * m, end: 0.7 * m },
    });
    faulty.faults = Some(plan);
    let out = e.serve_continuous(&reqs, &faulty, &ccfg).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.metrics.len(), reqs.len(),
               "the outage must not lose requests");
    assert_eq!(out.tokens, base.tokens,
               "outage under a class-aware flood changed the function");
    assert!(out.expert_stats.failover_fetches > 0,
            "no fetch rehomed off the downed shard");

    // Judge both tiers against a mid-range TTFT target: the weighted
    // queues served all three interactive requests within the first
    // few slots, so they must attain at least as well as — here,
    // strictly better than — the flood they cut ahead of.
    let mut ttfts: Vec<f64> = out.metrics.iter().map(|r| r.ttft).collect();
    ttfts.sort_by(f64::total_cmp);
    let spec = SloSpec { ttft: ttfts[ttfts.len() / 2], e2e: f64::INFINITY };
    let int = slo_attainment_for_class(&out.metrics, &spec,
                                       PriorityClass::Interactive);
    let batch = slo_attainment_for_class(&out.metrics, &spec,
                                         PriorityClass::Batch);
    assert_eq!(int.n_requests, 3);
    assert_eq!(batch.n_requests, 7);
    assert!(int.ttft_attainment >= batch.ttft_attainment,
            "interactive attainment {} fell below batch {} under faults",
            int.ttft_attainment, batch.ttft_attainment);
    assert!(int.ttft_attainment > batch.ttft_attainment,
            "flood order should separate the tiers strictly");
    assert!((int.ttft_attainment - 1.0).abs() < 1e-12,
            "every interactive request should beat the median TTFT");
}

#[test]
fn random_fault_plans_never_panic_and_preserve_goldens() {
    const CASES: u64 = 6;
    let e = engine();
    let reqs = short_requests(&e, 4, 71);
    let base_bulk = e.serve(&reqs, &opts(PolicyKind::DuoServe)).unwrap();
    let mut open = reqs.clone();
    assign_arrivals(&mut open,
                    &ArrivalProcess::Poisson { rate: 4.0, seed: 5 });
    let ccfg = ContinuousConfig { max_in_flight: 3, queue_capacity: 16,
                                  ..ContinuousConfig::default() };

    for case in 0..CASES {
        let mut rng = Rng::seed_from(case ^ 0xC0A5_7A11);
        let plan = random_plan(&mut rng);
        for sharded in [false, true] {
            let mut o = opts(PolicyKind::DuoServe);
            o.shards = if sharded { Some(2) } else { None };
            let base_tokens = if sharded {
                e.serve(&reqs, &o).unwrap().tokens
            } else {
                base_bulk.tokens.clone()
            };
            o.faults = Some(plan.clone());

            let bulk = e.serve(&reqs, &o).unwrap();
            assert!(bulk.oom.is_none(), "case {case} sharded={sharded}");
            assert_eq!(bulk.tokens, base_tokens,
                       "case {case} sharded={sharded}: plan {plan:?} \
                        changed phase-bulk tokens");
            ledger_invariants(&bulk.expert_stats, case);

            let cont = e.serve_continuous(&open, &o, &ccfg).unwrap();
            assert!(cont.oom.is_none(), "case {case} sharded={sharded}");
            assert_eq!(cont.tokens, base_tokens,
                       "case {case} sharded={sharded}: plan {plan:?} \
                        changed continuous tokens");
            ledger_invariants(&cont.expert_stats, case);
        }
    }
}

fn ledger_invariants(stats: &duoserve::experts::ExpertStats, case: u64) {
    assert_eq!(stats.touches(), stats.hits + stats.misses,
               "case {case}: touch accounting broke");
    assert!(stats.degraded_acquires <= stats.touches(),
            "case {case}: degraded {} > touches {}",
            stats.degraded_acquires, stats.touches());
    assert!(stats.staging_poisoned <= stats.degraded_acquires,
            "case {case}: poisoned acquires not counted as degraded");
}

/// A small random plan: 1-3 clauses over windows inside the first few
/// virtual seconds (tiny-model runs finish well within that).
fn random_plan(rng: &mut Rng) -> FaultPlan {
    let window = |rng: &mut Rng| {
        let start = rng.f64() * 0.2;
        let end = if rng.bool_with(0.2) {
            f64::INFINITY
        } else {
            start + rng.f64() * 2.0
        };
        Window { start, end }
    };
    let mut plan = FaultPlan { seed: rng.below(1000) as u64,
                               ..FaultPlan::default() };
    for _ in 0..rng.range(1, 3) {
        match rng.below(5) {
            0 => plan.outages.push(ShardOutage {
                shard: rng.below(2),
                window: window(rng),
            }),
            1 => plan.fetch_fails.push(FetchFail {
                prob: rng.f64(),
                link: LinkSel::All,
                window: window(rng),
            }),
            2 => plan.link_slows.push(LinkSlow {
                factor: 1.0 + 3.0 * rng.f64(),
                link: if rng.bool_with(0.5) {
                    LinkSel::Host
                } else {
                    LinkSel::Peer
                },
                window: window(rng),
            }),
            3 => plan.worker_stalls.push(window(rng)),
            _ => plan.worker_poison = true,
        }
    }
    plan
}
