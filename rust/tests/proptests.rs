//! Property-based tests over the coordinator's invariants, using the
//! in-tree seeded RNG as the case generator (no proptest crate in the
//! offline image — same discipline: many random cases, shrunk seeds
//! reported on failure via the assert message).

use duoserve::experts::{ExpertProvider, StagedExpertProvider};
use duoserve::memory::{DeviceExpertCache, ExpertKey};
use duoserve::metrics::percentile;
use duoserve::predictor::top_k;
use duoserve::simx::{StreamId, Streams};
use duoserve::util::{Json, Rng};

const CASES: u64 = 200;

// ---------------- cache invariants -------------------------------------

#[test]
fn prop_cache_never_exceeds_capacity_or_window() {
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed);
        let cap = r.range(1, 8);
        let window = r.range(0, 3);
        let mut c = DeviceExpertCache::new(cap, window);
        for step in 0..100 {
            let key = ExpertKey::routed(r.below(12), r.below(16));
            if r.bool_with(0.7) {
                c.insert(key, step as f64, step as f64);
            } else {
                c.touch(key, step as f64);
            }
            // capacity per layer
            for layer in 0..12 {
                assert!(c.resident_in_layer(layer).len() <= cap,
                        "seed {seed}: layer over capacity");
            }
            if window > 0 {
                let mut layers: Vec<usize> = (0..12)
                    .filter(|&l| !c.resident_in_layer(l).is_empty())
                    .collect();
                layers.dedup();
                assert!(layers.len() <= window,
                        "seed {seed}: window violated: {layers:?}");
            }
        }
    }
}

#[test]
fn prop_provider_hits_plus_misses_equals_touches() {
    // Hit/miss accounting lives in the ExpertProvider's ledger (the
    // cache itself no longer counts): every touch is exactly one hit
    // or one miss, and admitted bytes track admissions.
    let expert_bytes = 7u64;
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0xABCD);
        let mut p = StagedExpertProvider::detached(
            DeviceExpertCache::new(4, 0), expert_bytes);
        let mut touches = 0u64;
        let mut admits = 0u64;
        for i in 0..200 {
            let key = ExpertKey::routed(r.below(4), r.below(8));
            if r.bool_with(0.5) {
                p.touch(key, i as f64);
                touches += 1;
            } else {
                p.admit(key, i as f64, i as f64);
                admits += 1;
            }
        }
        let s = p.stats();
        assert_eq!(s.hits + s.misses, touches, "seed {seed}");
        assert_eq!(s.touches(), touches, "seed {seed}");
        assert_eq!(s.bytes_fetched, admits * expert_bytes, "seed {seed}");
    }
}

// ---------------- stream timeline invariants ---------------------------

#[test]
fn prop_stream_ops_never_overlap_within_stream() {
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0x5EED);
        let mut s = Streams::recording();
        for _ in 0..60 {
            let stream = match r.below(3) {
                0 => StreamId::Compute,
                1 => StreamId::Comm,
                _ => StreamId::Predict,
            };
            let ready = r.f64() * 5.0;
            let dur = r.f64() * 0.3;
            s.run(stream, ready, dur, "op");
        }
        for sid in [StreamId::Compute, StreamId::Comm, StreamId::Predict] {
            let mut ops: Vec<_> = s
                .trace()
                .iter()
                .filter(|o| o.stream == sid)
                .collect();
            ops.sort_by(|a, b| a.start.total_cmp(&b.start));
            for w in ops.windows(2) {
                assert!(w[0].end <= w[1].start + 1e-12,
                        "seed {seed}: intra-stream overlap");
            }
        }
    }
}

#[test]
fn prop_stream_completion_monotone_in_issue_order() {
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0xF00D);
        let mut s = Streams::new();
        let mut last = 0.0;
        for _ in 0..50 {
            let t = s.run(StreamId::Comm, r.f64(), r.f64() * 0.1, "x");
            assert!(t >= last, "seed {seed}: completion regressed");
            last = t;
        }
    }
}

#[test]
fn prop_op_starts_respect_ready_time() {
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0xBEEF);
        let mut s = Streams::recording();
        for _ in 0..40 {
            let ready = r.f64() * 2.0;
            let end = s.run(StreamId::Compute, ready, 0.01, "op");
            assert!(end >= ready + 0.01 - 1e-12, "seed {seed}");
        }
        for op in s.trace() {
            assert!(op.end - op.start >= 0.0);
        }
    }
}

// ---------------- top-k / percentile -----------------------------------

#[test]
fn prop_top_k_is_the_k_largest() {
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0x70C0);
        let n = r.range(1, 64);
        let k = r.range(1, n);
        let scores: Vec<f32> = (0..n).map(|_| r.f64() as f32).collect();
        let sel = top_k(&scores, k);
        assert_eq!(sel.len(), k, "seed {seed}");
        // every selected >= every unselected
        let min_sel = sel
            .iter()
            .map(|&i| scores[i])
            .fold(f32::INFINITY, f32::min);
        for i in 0..n {
            if !sel.contains(&i) {
                assert!(scores[i] <= min_sel + 1e-9, "seed {seed}");
            }
        }
        // sorted, unique
        for w in sel.windows(2) {
            assert!(w[0] < w[1], "seed {seed}: not sorted-unique");
        }
    }
}

#[test]
fn prop_percentile_bounds_and_monotonicity() {
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0x9C7);
        let n = r.range(1, 100);
        let mut v: Vec<f64> = (0..n).map(|_| r.f64() * 10.0).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        let p50 = percentile(&v, 50.0);
        let p95 = percentile(&v, 95.0);
        assert!(p50 <= p95, "seed {seed}");
        assert!(p95 <= *v.last().unwrap() + 1e-12, "seed {seed}");
        assert!(p50 >= v[0] - 1e-12, "seed {seed}");
    }
}

// ---------------- json round-trip ---------------------------------------

#[test]
fn prop_json_roundtrip_random_trees() {
    fn gen(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.bool_with(0.5)),
            2 => Json::Num((r.below(2_000_000) as f64) - 1_000_000.0),
            3 => Json::Str(format!("s{}-\"q\"\n", r.below(1000))),
            4 => Json::Arr((0..r.below(5)).map(|_| gen(r, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..r.below(5) {
                    m.insert(format!("k{i}"), gen(r, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    for seed in 0..CASES {
        let mut r = Rng::seed_from(seed ^ 0x15_0A);
        let v = gen(&mut r, 3);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}");
    }
}
