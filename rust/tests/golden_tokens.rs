//! Cross-language correctness: the rust engine (separately-lowered
//! components + host-side combine, orchestrated by the coordinator)
//! must reproduce the python ReferenceModel's generations
//! token-for-token and route-for-route, for every scheduling policy
//! (policies change *time*, never *function*).
//!
//! Goldens are written by `python -m compile.aot` (artifacts/<model>/
//! goldens.json). Requires `make artifacts-tiny`.

use std::path::PathBuf;

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ContinuousConfig, Engine, ServeOptions};
use duoserve::util::Json;
use duoserve::workload::Request;

fn artifacts_dir() -> PathBuf {
    duoserve::testkit::ensure_tiny()
}

fn load_goldens(engine: &Engine) -> Vec<Json> {
    let path = engine.man.resolve(&engine.man.goldens);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing goldens {path:?}: {e} \
                                    (run `make artifacts-tiny`)"));
    Json::parse(&text).unwrap().as_arr().unwrap().to_vec()
}

fn golden_request(g: &Json, id: usize) -> Request {
    Request {
        req_id: id,
        dataset: g.get("dataset").unwrap().as_str().unwrap().to_string(),
        cluster: 0,
        prompt: g.get("prompt").unwrap().i32_vec().unwrap(),
        n_decode: g.get("n_decode").unwrap().as_usize().unwrap(),
        arrival: 0.0,
        class: Default::default(),
    }
}

fn check_policy(policy: PolicyKind) {
    let engine = Engine::load(&artifacts_dir(), "mixtral-tiny").unwrap();
    let goldens = load_goldens(&engine);
    assert!(!goldens.is_empty());
    let opts = ServeOptions::new(policy, DeviceProfile::a6000());

    for (i, g) in goldens.iter().enumerate() {
        let req = golden_request(g, i);
        let out = engine.serve(std::slice::from_ref(&req), &opts).unwrap();
        assert!(out.oom.is_none(), "unexpected OOM under {policy:?}");
        let want: Vec<i32> = g.get("tokens").unwrap().i32_vec().unwrap();
        assert_eq!(out.tokens[0], want,
                   "golden {i} tokens diverged under {policy:?}");
    }
}

#[test]
fn duoserve_matches_reference_tokens() {
    check_policy(PolicyKind::DuoServe);
}

#[test]
fn odf_matches_reference_tokens() {
    check_policy(PolicyKind::Odf);
}

#[test]
fn lfp_matches_reference_tokens() {
    check_policy(PolicyKind::Lfp);
}

#[test]
fn mif_matches_reference_tokens() {
    check_policy(PolicyKind::Mif);
}

#[test]
fn decode_routing_matches_reference() {
    // Beyond tokens: the per-layer expert selections of every decode
    // step must match the reference's routing trace exactly.
    let engine = Engine::load(&artifacts_dir(), "mixtral-tiny").unwrap();
    let goldens = load_goldens(&engine);
    let opts = ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());

    for (i, g) in goldens.iter().enumerate() {
        let req = golden_request(g, i);
        let out = engine.serve(std::slice::from_ref(&req), &opts).unwrap();
        // decode_routing: [step][layer][k] from the reference model
        let want: Vec<Vec<Vec<usize>>> = g
            .get("decode_routing")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|step| {
                step.as_arr()
                    .unwrap()
                    .iter()
                    .map(|l| {
                        let mut v = l.usize_vec().unwrap();
                        v.sort_unstable();
                        v
                    })
                    .collect()
            })
            .collect();
        let got = &out.episodes[0].steps;
        assert_eq!(got.len(), want.len(), "golden {i}: step count");
        for (s, (gs, ws)) in got.iter().zip(&want).enumerate() {
            assert_eq!(gs, ws, "golden {i} step {s}: routing diverged");
        }
    }
}

#[test]
fn continuous_serve_matches_goldens_with_interleaved_requests() {
    // The continuous loop admits prefills *between* decode iterations,
    // so staggered arrivals interleave one request's prefill with
    // others' decodes over shared engine state — exactly where a KV
    // aliasing bug after the zero-copy refactor would corrupt a token
    // stream. Every request must still reproduce its golden exactly.
    let engine = Engine::load(&artifacts_dir(), "mixtral-tiny").unwrap();
    let goldens = load_goldens(&engine);
    assert!(!goldens.is_empty());
    let reqs: Vec<Request> = goldens
        .iter()
        .enumerate()
        .map(|(i, g)| {
            let mut r = golden_request(g, i);
            r.arrival = i as f64 * 0.002;
            r
        })
        .collect();
    let opts = ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a6000());
    let ccfg = ContinuousConfig {
        max_in_flight: 2,
        queue_capacity: goldens.len().max(4),
        ..ContinuousConfig::default()
    };
    let out = engine.serve_continuous(&reqs, &opts, &ccfg).unwrap();
    assert!(out.oom.is_none());
    assert_eq!(out.rejected, 0, "goldens must not be queue-rejected");
    for (i, g) in goldens.iter().enumerate() {
        let want: Vec<i32> = g.get("tokens").unwrap().i32_vec().unwrap();
        assert_eq!(out.tokens[i], want,
                   "continuous-mode golden {i} tokens diverged");
    }

    // And continuous must equal phase-bulk on the same request set.
    let bulk_reqs: Vec<Request> = goldens
        .iter()
        .enumerate()
        .map(|(i, g)| golden_request(g, i))
        .collect();
    let bulk = engine.serve(&bulk_reqs, &opts).unwrap();
    assert_eq!(out.tokens, bulk.tokens,
               "continuous vs phase-bulk token streams diverged");
}

#[test]
fn policies_produce_identical_tokens() {
    // Function/time split: all four policies must emit identical text.
    let engine = Engine::load(&artifacts_dir(), "mixtral-tiny").unwrap();
    let goldens = load_goldens(&engine);
    let req = golden_request(&goldens[0], 0);
    let mut all = Vec::new();
    for policy in PolicyKind::ALL {
        let opts = ServeOptions::new(policy, DeviceProfile::a6000());
        let out = engine.serve(std::slice::from_ref(&req), &opts).unwrap();
        all.push(out.tokens[0].clone());
    }
    for w in all.windows(2) {
        assert_eq!(w[0], w[1]);
    }
}
