//! Minimal drop-in subset of the `anyhow` crate for the offline build
//! image (no registry access). Implements exactly the surface this
//! repository uses: `Error`, `Result`, the `Context` trait on `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! `Error` is a context chain rendered eagerly into strings — no
//! downcasting (nothing in-tree downcasts). Like real `anyhow`,
//! `Error` deliberately does NOT implement `std::error::Error`, so the
//! blanket `From<E: std::error::Error>` impl cannot overlap with
//! `From<Error>`.

use std::fmt;

/// An error chain: `chain[0]` is the outermost context message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context()` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(msg)` / `.with_context(|| msg)` on results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_displays_outermost() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f() -> Result<()> {
            ensure!(1 + 1 == 2);
            ensure!(false, "boom {}", 7);
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(g().is_err());
    }
}
