//! End-to-end serving driver (the repo's headline validation run):
//! load a real (scaled) MoE model from AOT artifacts and serve batched
//! request workloads through the full stack — admission queue, batch
//! composer, dual-phase engine — reporting latency and throughput at
//! several batch sizes. This is the run recorded in EXPERIMENTS.md
//! §End-to-end.
//!
//!     cargo run --release --example serve_workload -- \
//!         [model] [device] [requests-per-batch-sweep]

use std::path::Path;

use anyhow::Result;

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{BatchComposer, Engine, RequestQueue, ServeOptions};
use duoserve::metrics::{fmt_gb, fmt_secs, summarize, Table};
use duoserve::workload::generate_requests;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("mixtral8x7b-sim");
    let device = args
        .get(1)
        .and_then(|d| DeviceProfile::by_name(d))
        .unwrap_or_else(DeviceProfile::a5000);
    let n_requests: usize =
        args.get(2).and_then(|v| v.parse().ok()).unwrap_or(8);

    let engine = Engine::load(Path::new("artifacts"), model)?;
    println!("serving {model} on simulated {} — {} requests per batch size\n",
             device.name, n_requests);

    let mut table = Table::new(&[
        "batch", "mean TTFT", "mean E2E", "P95 E2E", "tokens/s", "peak mem",
    ]);
    for batch_size in [1usize, 2, 4, 8] {
        // Admission: requests arrive, the queue applies backpressure,
        // the composer forms serving batches.
        let mut queue = RequestQueue::new(256);
        for r in generate_requests(&engine.man, "squad", n_requests, 99) {
            queue.push(r);
        }
        let batches = BatchComposer::new(batch_size).compose(&mut queue);

        let opts = ServeOptions::new(PolicyKind::DuoServe, device.clone());
        let mut all_metrics = Vec::new();
        let mut makespan = 0.0;
        let mut peak = 0u64;
        for batch in &batches {
            let out = engine.serve(batch, &opts)?;
            if let Some(oom) = out.oom {
                println!("batch={batch_size}: {oom}");
                break;
            }
            makespan += out.summary.makespan;
            peak = peak.max(out.peak_bytes);
            all_metrics.extend(out.metrics);
        }
        let s = summarize(&all_metrics, makespan);
        table.row(vec![
            batch_size.to_string(),
            fmt_secs(s.mean_ttft),
            fmt_secs(s.mean_e2e),
            fmt_secs(s.p95_e2e),
            format!("{:.1}", s.total_tokens as f64 / makespan),
            fmt_gb(peak),
        ]);
    }
    println!("{}", table.render());
    println!("(E2E at batch > 1 includes lockstep queueing — the Fig. 7 \
              throughput/latency trade-off)");
    Ok(())
}
