//! Open-loop serving under an arrival stream — the continuous-batching
//! counterpart of `serve_workload`. A Poisson request stream is played
//! through the event-driven serving loop at several arrival rates;
//! TTFT/E2E are measured from each request's arrival (queueing delay
//! included) and reported together with SLO attainment, DuoServe vs
//! the on-demand-fetch baseline.
//!
//!     cargo run --release --example serve_stream -- \
//!         [model] [device] [requests]

use anyhow::Result;

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{ContinuousConfig, Engine, ServeOptions};
use duoserve::metrics::{fmt_secs, slo_attainment, SloSpec};
use duoserve::workload::{assign_arrivals, generate_requests, ArrivalProcess};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("mixtral-tiny");
    let device = args
        .get(1)
        .and_then(|d| DeviceProfile::by_name(d))
        .unwrap_or_else(DeviceProfile::a5000);
    let n_requests: usize =
        args.get(2).and_then(|v| v.parse().ok()).unwrap_or(12);

    let artifacts = duoserve::testkit::ensure_model(model);
    let engine = Engine::load(&artifacts, model)?;
    let ccfg = ContinuousConfig { max_in_flight: 4, queue_capacity: 64,
                                  ..ContinuousConfig::default() };

    // Calibrate the SLO from an unloaded run: a single request served
    // on an idle engine defines the no-queueing baseline.
    let mut probe = generate_requests(&engine.man, "squad", 1, 7);
    assign_arrivals(&mut probe, &ArrivalProcess::Closed);
    let duo_opts = ServeOptions::new(PolicyKind::DuoServe, device.clone());
    let base = engine.serve_continuous(&probe, &duo_opts, &ccfg)?;
    let spec = SloSpec {
        ttft: base.metrics[0].ttft * 2.0,
        e2e: base.metrics[0].e2e * 2.0,
    };
    println!("{model} on simulated {}, {} requests; SLO ttft<={} e2e<={}\n",
             device.name, n_requests, fmt_secs(spec.ttft),
             fmt_secs(spec.e2e));

    for rate in [0.5, 2.0, 8.0] {
        println!("arrival rate {rate:.1} req/s (Poisson):");
        for pol in [PolicyKind::Odf, PolicyKind::DuoServe] {
            let mut reqs =
                generate_requests(&engine.man, "squad", n_requests, 99);
            assign_arrivals(&mut reqs,
                            &ArrivalProcess::Poisson { rate, seed: 5 });
            let opts = ServeOptions::new(pol, device.clone());
            let out = engine.serve_continuous(&reqs, &opts, &ccfg)?;
            if let Some(oom) = out.oom {
                println!("  {:>8}: {oom}", pol.label());
                continue;
            }
            let rep = slo_attainment(&out.metrics, &spec);
            println!(
                "  {:>8}: p50-ttft {:>8} p95-ttft {:>8} p95-e2e {:>8} \
                 attainment ttft {:>5.1}% e2e {:>5.1}% rejected {}",
                pol.label(),
                fmt_secs(out.summary.p50_ttft),
                fmt_secs(out.summary.p95_ttft),
                fmt_secs(out.summary.p95_e2e),
                rep.ttft_attainment * 100.0,
                rep.e2e_attainment * 100.0,
                out.rejected,
            );
        }
        println!();
    }
    println!("(TTFT/E2E measured from arrival: queueing delay included.\n\
              DuoServe's faster prefill/decode drains the queue sooner, \
              which is where SLO attainment under load comes from.)");
    Ok(())
}
