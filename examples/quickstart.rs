//! Quickstart: load a model's AOT artifacts (self-generated on first
//! run), serve one request with DuoServe-MoE scheduling, print the
//! generated tokens and QoS metrics.
//!
//!     cargo run --release --example quickstart
//!
//! Optional args: [model] [device], e.g.
//!     cargo run --release --example quickstart -- mixtral8x7b-sim a6000

use anyhow::Result;

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{Engine, ServeOptions};
use duoserve::metrics::{fmt_gb, fmt_secs};
use duoserve::workload::generate_requests;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("mixtral-tiny");
    let device = args
        .get(1)
        .and_then(|d| DeviceProfile::by_name(d))
        .unwrap_or_else(DeviceProfile::a5000);

    // 1. Load the engine: every lowered component plus the host
    //    expert pool (artifacts are generated on first use).
    let artifacts = duoserve::testkit::ensure_model(model);
    let engine = Engine::load(&artifacts, model)?;
    println!("loaded {model}: {} layers, {} experts (top-{}), \
              serving on simulated {}",
             engine.man.sim.n_layers, engine.man.sim.n_experts,
             engine.man.sim.top_k, device.name);

    // 2. One SQuAD-shaped request.
    let request = &generate_requests(&engine.man, "squad", 1, 1234)[0];
    println!("prompt: {} tokens, want {} output tokens",
             request.prompt.len(), request.n_decode);

    // 3. Serve under the paper's dual-phase scheduling.
    let opts = ServeOptions::new(PolicyKind::DuoServe, device);
    let out = engine.serve(std::slice::from_ref(request), &opts)?;
    if let Some(oom) = out.oom {
        println!("OOM: {oom}");
        return Ok(());
    }

    // 4. Results.
    let m = &out.metrics[0];
    println!("\ntokens: {:?}", out.tokens[0]);
    println!("TTFT            {}", fmt_secs(m.ttft));
    println!("E2E latency     {}", fmt_secs(m.e2e));
    println!("mean step       {}", fmt_secs(
        m.step_latencies.iter().sum::<f64>()
            / m.step_latencies.len().max(1) as f64));
    println!("cache hit rate  {:.1}%", out.hit_rate * 100.0);
    println!("predictor acc   {:.1}% exact / {:.1}% at-least-half",
             out.accuracy.exact_rate() * 100.0,
             out.accuracy.half_rate() * 100.0);
    println!("peak GPU memory {}", fmt_gb(out.peak_bytes));
    Ok(())
}
