//! QoS comparison across all four scheduling policies on one model —
//! a compact, runnable version of the paper's Fig. 5/6 story with the
//! stream-utilisation view that explains *why* DuoServe wins (overlap).
//!
//!     cargo run --release --example qos_comparison -- [model] [device]

use std::path::Path;

use anyhow::Result;

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{Engine, ServeOptions};
use duoserve::metrics::{fmt_gb, fmt_secs, summarize, Table};
use duoserve::simx::StreamId;
use duoserve::workload::generate_requests;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("mixtral8x7b-sim");
    let device = args
        .get(1)
        .and_then(|d| DeviceProfile::by_name(d))
        .unwrap_or_else(DeviceProfile::a5000);

    let engine = Engine::load(Path::new("artifacts"), model)?;
    let reqs = generate_requests(&engine.man, "squad", 6, 7);

    let mut table = Table::new(&[
        "policy", "TTFT", "E2E", "P95", "hit%", "mem", "comm busy",
        "overlap%",
    ]);
    for policy in PolicyKind::ALL {
        let mut opts = ServeOptions::new(policy, device.clone());
        opts.record_streams = true;
        let mut ms = Vec::new();
        let mut peak = 0u64;
        let mut hit = 0.0;
        let mut comm_busy = 0.0;
        let mut overlap = 0.0;
        let mut span = 0.0;
        let mut oom = None;
        for r in &reqs {
            let out = engine.serve(std::slice::from_ref(r), &opts)?;
            if out.oom.is_some() {
                oom = out.oom;
                break;
            }
            peak = peak.max(out.peak_bytes);
            hit = out.hit_rate;
            span += out.summary.makespan;
            if let Some(trace) = &out.stream_trace {
                // comm busy time + how much of it is hidden behind
                // compute (the overlap the two-stream pipeline buys).
                let comms: Vec<_> = trace
                    .iter()
                    .filter(|o| o.stream == StreamId::Comm)
                    .collect();
                let computes: Vec<_> = trace
                    .iter()
                    .filter(|o| o.stream == StreamId::Compute)
                    .collect();
                for c in &comms {
                    comm_busy += c.end - c.start;
                    for k in &computes {
                        let lo = c.start.max(k.start);
                        let hi = c.end.min(k.end);
                        if hi > lo {
                            overlap += hi - lo;
                        }
                    }
                }
            }
            ms.extend(out.metrics);
        }
        if oom.is_some() {
            table.row(vec![policy.label().into(), "OOM".into(), "-".into(),
                           "-".into(), "-".into(), "-".into(), "-".into(),
                           "-".into()]);
            continue;
        }
        let s = summarize(&ms, span);
        table.row(vec![
            policy.label().into(),
            fmt_secs(s.mean_ttft),
            fmt_secs(s.mean_e2e),
            fmt_secs(s.p95_e2e),
            format!("{:.0}%", hit * 100.0),
            fmt_gb(peak),
            fmt_secs(comm_busy),
            format!("{:.0}%", 100.0 * overlap / comm_busy.max(1e-12)),
        ]);
    }
    println!("{model} on simulated {}, 6 squad requests:\n", device.name);
    println!("{}", table.render());
    println!("overlap% = fraction of host->device transfer time hidden \
              behind computation.\nDuoServe's dual-stream design buys \
              overlap without MIF's memory blowup.");
    Ok(())
}
