//! Offline-preprocess walkthrough from the rust side (paper Fig. 3,
//! left box): run the Experts Tracer over live serving, rebuild the
//! popularity / affinity matrices (Eq. 2–3), and pit the deployed
//! ExpertMLP against the popularity x affinity heuristic on the traces
//! just collected — the Challenge-#1 ablation ("a heuristic based
//! solely on these patterns would not achieve high accuracy").
//!
//!     cargo run --release --example trace_and_predict -- [model]

use std::path::Path;

use anyhow::Result;

use duoserve::config::{DeviceProfile, PolicyKind};
use duoserve::coordinator::{Engine, ServeOptions};
use duoserve::metrics::{PredictorAccuracy, Table};
use duoserve::predictor::{HeuristicKind, HeuristicPredictor,
                          StateConstructor, Tracer};
use duoserve::workload::generate_requests;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("mixtral8x7b-sim");
    let engine = Engine::load(Path::new("artifacts"), model)?;
    let (l, e, k) = (engine.man.sim.n_layers, engine.man.sim.n_experts,
                     engine.man.sim.top_k);

    // ---- 1. trace collection alongside real serving -----------------
    let opts = ServeOptions::new(PolicyKind::DuoServe, DeviceProfile::a5000());
    let mut tracer = Tracer::new();
    for r in &generate_requests(&engine.man, "orca", 6, 2024) {
        let out = engine.serve(std::slice::from_ref(r), &opts)?;
        for ep in out.episodes {
            tracer.begin_episode(&ep.dataset);
            for step in ep.steps {
                tracer.record_step(step);
            }
            tracer.end_episode();
        }
    }
    println!("collected {} episodes", tracer.episodes().len());

    // ---- 2. Fig. 2 statistics ---------------------------------------
    let pop = tracer.popularity(l, e);
    println!("\npopularity (layer 0): {:?}",
             pop[0].iter().map(|p| (p * 100.0).round() / 100.0)
                 .collect::<Vec<_>>());
    let aff = tracer.affinity(l, e);
    let row_max: f64 = aff[0]
        .iter()
        .map(|row| row.iter().cloned().fold(0.0, f64::max))
        .sum::<f64>() / e as f64;
    println!("affinity layer0->1 mean row-max: {row_max:.3} \
              (uniform would be {:.3})", 1.0 / e as f64);

    // ---- 3. predictor vs heuristics on the fresh traces -------------
    let mlp_label = "ExpertMLP (DuoServe)";
    let mut accs: Vec<(&str, PredictorAccuracy)> = vec![
        (mlp_label, PredictorAccuracy::default()),
        ("popularity-only", PredictorAccuracy::default()),
        ("popularity x affinity", PredictorAccuracy::default()),
    ];
    let hp = HeuristicPredictor::new(HeuristicKind::Popularity, k);
    let ha = HeuristicPredictor::new(HeuristicKind::PopularityAffinity, k);

    for ep in tracer.episodes() {
        for step in &ep.steps {
            let mut sc = StateConstructor::new(&engine.man);
            for (layer, sel) in step.iter().enumerate() {
                if layer >= 1 {
                    let pm = engine.predict_layer(&sc, layer)?;
                    accs[0].1.observe(&pm, sel);
                    accs[1].1.observe(
                        &hp.predict(&engine.mats, layer, &step[layer - 1]), sel);
                    accs[2].1.observe(
                        &ha.predict(&engine.mats, layer, &step[layer - 1]), sel);
                }
                sc.record(layer, sel);
            }
        }
    }

    let mut t = Table::new(&["predictor", "top-k exact", "at-least-half"]);
    for (name, acc) in &accs {
        t.row(vec![
            name.to_string(),
            format!("{:.2}%", acc.exact_rate() * 100.0),
            format!("{:.2}%", acc.half_rate() * 100.0),
        ]);
    }
    println!("\n{}", t.render());
    println!("(the learned predictor must beat both heuristics — \
              paper §II-A Challenge #1)");
    Ok(())
}
