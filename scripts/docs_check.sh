#!/usr/bin/env bash
# Offline documentation checks (CI `docs` job / `make docs-check`):
#
#   1. every intra-repo markdown link in README.md and docs/*.md
#      resolves to an existing file or directory;
#   2. docs/CLI.md documents every CLI flag string the binary parses
#      (the `args.str("name", ...)` / `args.usize(...)` / `args.flag`
#      sites in rust/src/main.rs).
#
# No network, no toolchain: plain grep/sed over the tree.
set -u
cd "$(dirname "$0")/.."
errors=0

# --- 1. intra-repo markdown links ------------------------------------
for f in README.md docs/*.md; do
    [ -f "$f" ] || continue
    dir=$(dirname "$f")
    # inline links: [text](target) — one per line via -o
    for target in $(grep -oE '\]\([^) ]+\)' "$f" \
                        | sed -E 's/^\]\(//; s/\)$//'); do
        case "$target" in
            http://*|https://*|mailto:*|\#*) continue ;;
        esac
        path="${target%%#*}"
        [ -z "$path" ] && continue
        if [ ! -e "$dir/$path" ] && [ ! -e "$path" ]; then
            echo "docs-check: $f: broken link -> $target"
            errors=1
        fi
    done
done

# --- 2. CLI flag coverage --------------------------------------------
if [ ! -f docs/CLI.md ]; then
    echo "docs-check: docs/CLI.md is missing"
    errors=1
else
    flags=$(grep -oE 'args\.(str|usize|u64|f64|flag|require)\("[a-z0-9-]+"' \
                 rust/src/main.rs \
                | sed -E 's/.*\("//; s/"$//' | sort -u)
    if [ -z "$flags" ]; then
        echo "docs-check: found no flags in rust/src/main.rs (pattern rot?)"
        errors=1
    fi
    for fl in $flags; do
        if ! grep -q -- "--$fl" docs/CLI.md; then
            echo "docs-check: docs/CLI.md does not mention --$fl"
            errors=1
        fi
    done
fi

if [ "$errors" -eq 0 ]; then
    echo "docs-check OK"
fi
exit "$errors"
